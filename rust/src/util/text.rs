//! Small string helpers shared across layers.

/// Filesystem-safe form of an identifier: every char that is not
/// ASCII-alphanumeric, `-` or `_` becomes `_`.  Used for experiment
/// page/badge file names (`session`) and run-store shard names
/// (`store`) — one function, so the two layers can never disagree
/// about what an id looks like on disk.
pub fn slug(id: &str) -> String {
    id.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slug_sanitizes() {
        assert_eq!(slug("mesh_1/strong scaling"), "mesh_1_strong_scaling");
        assert_eq!(slug("a-b_c9"), "a-b_c9");
        assert_eq!(slug(""), "");
        assert_eq!(slug("."), "_");
    }
}
