//! Small statistics helpers shared by the simulator, the bench harness
//! and the report generator.

/// Online mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Welford {
        Welford::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Relative stddev (the paper reports runtime as "125s (0.1%)").
    pub fn rel_stddev(&self) -> f64 {
        if self.mean.abs() < f64::MIN_POSITIVE {
            0.0
        } else {
            self.stddev() / self.mean.abs()
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

/// Format seconds with engineering-friendly units (bench + tables).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 100.0 {
        format!("{secs:.0}s")
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2}us", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Format byte counts (Table 2 reports GB).
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}KB", b / 1e3)
    } else {
        format!("{bytes}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let naive_var =
            xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.variance() - naive_var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_duration(125.0), "125s");
        assert_eq!(fmt_duration(0.002), "2.00ms");
        assert_eq!(fmt_bytes(29_000_000_000), "29.00GB");
        assert_eq!(fmt_bytes(512), "512B");
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        let w = Welford::new();
        assert_eq!(w.stddev(), 0.0);
        assert_eq!(w.rel_stddev(), 0.0);
    }
}
