//! Minimal JSON value model, parser and writer.
//!
//! serde_json is unavailable in this offline image (see Cargo.toml note),
//! so the TALP JSON schema, the artifact manifest and the CI metadata all
//! go through this module.  It implements RFC 8259 minus some laxities:
//! no `\u` surrogate-pair validation beyond replacement, numbers are f64
//! (TALP times are ns-as-integers < 2^53, safe in f64), object key order
//! is preserved (Vec-backed) so reports render deterministically.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset and human context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- constructors ----------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---------- accessors ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Insert or replace a key in an object; panics on non-objects
    /// (programming error, not data error).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(pairs) => {
                if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Path lookup: `j.at(&["region", "useful_time"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: f64 field lookup with default.
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    // ---------- serialization ----------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::with_capacity(1024);
        self.write(&mut out, Some(0));
        out.push('\n');
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write(&mut out, None);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent.map(|d| d + 1));
                    item.write(out, indent.map(|d| d + 1));
                }
                newline_indent(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent.map(|d| d + 1));
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent.map(|d| d + 1));
                }
                newline_indent(out, indent);
                out.push('}');
            }
        }
    }

    // ---------- parsing ----------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing data after document"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>) {
    if let Some(d) = indent {
        out.push('\n');
        for _ in 0..d * 2 {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; TALP metrics never produce them, but be
        // defensive rather than emit invalid documents.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        let s = format!("{n}");
        out.push_str(&s);
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue; // unicode_escape advanced pos itself
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str, so valid).
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos += ch_len;
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        // at '\\u'; pos points at 'u'
        self.pos += 1;
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let hex_str =
            std::str::from_utf8(hex).map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex_str, 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        // Surrogate pair handling.
        if (0xd800..0xdc00).contains(&cp) {
            if self.bytes.get(self.pos) == Some(&b'\\')
                && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 2;
                let hex2 = self
                    .bytes
                    .get(self.pos..self.pos + 4)
                    .ok_or_else(|| self.err("truncated low surrogate"))?;
                let lo = u32::from_str_radix(
                    std::str::from_utf8(hex2).map_err(|_| self.err("bad"))?,
                    16,
                )
                .map_err(|_| self.err("bad low surrogate"))?;
                self.pos += 4;
                let c = 0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                return char::from_u32(c).ok_or_else(|| self.err("bad pair"));
            }
            return Ok('\u{fffd}');
        }
        Ok(char::from_u32(cp).unwrap_or('\u{fffd}'))
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Sort an object's keys recursively (for canonical comparisons in tests).
pub fn canonicalize(j: &Json) -> Json {
    match j {
        Json::Obj(pairs) => {
            let map: BTreeMap<String, Json> = pairs
                .iter()
                .map(|(k, v)| (k.clone(), canonicalize(v)))
                .collect();
            Json::Obj(map.into_iter().collect())
        }
        Json::Arr(items) => Json::Arr(items.iter().map(canonicalize).collect()),
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_types() {
        for text in [
            "null",
            "true",
            "false",
            "0",
            "-12",
            "3.5",
            "1e3",
            "\"hi\"",
            "[]",
            "{}",
            "[1,2,3]",
            "{\"a\":1,\"b\":[true,null]}",
        ] {
            let v = Json::parse(text).unwrap();
            let re = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, re, "{text}");
        }
    }

    #[test]
    fn parses_nested_and_preserves_order() {
        let v = Json::parse(r#"{"z":1,"a":{"k":[1,2,{"x":"y"}]}}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a"]);
        assert_eq!(
            v.at(&["a", "k"]).unwrap().as_arr().unwrap().len(),
            3
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\n\ttab \"quote\" back\\slash \u{263a}";
        let j = Json::Str(s.to_string());
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""A☺""#).unwrap().as_str().unwrap(),
            "A\u{263a}"
        );
        assert_eq!(
            Json::parse(r#""😀""#).unwrap().as_str().unwrap(),
            "\u{1f600}"
        );
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\"}", "nul", "01x", "\"abc", "[1] junk"] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn integers_render_without_fraction() {
        let j = Json::Num(1234567890.0);
        assert_eq!(j.to_string_compact(), "1234567890");
        let j = Json::Num(0.25);
        assert_eq!(j.to_string_compact(), "0.25");
    }

    #[test]
    fn set_and_get() {
        let mut o = Json::obj();
        o.set("x", Json::Num(1.0));
        o.set("x", Json::Num(2.0));
        o.set("y", Json::Str("v".into()));
        assert_eq!(o.num_or("x", 0.0), 2.0);
        assert_eq!(o.str_or("y", ""), "v");
        assert_eq!(o.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":null}}"#).unwrap();
        let pretty = v.to_string_pretty();
        assert!(pretty.contains("\n"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn canonicalize_sorts_keys() {
        let a = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"b":1}"#).unwrap();
        assert_ne!(a, b);
        assert_eq!(canonicalize(&a), canonicalize(&b));
    }

    #[test]
    fn large_integer_precision_preserved() {
        // ns timestamps fit in f64's 2^53 integer range.
        let t = 1_720_000_000_000_000_000u64 / 1000; // us precision
        let j = Json::Num(t as f64);
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap().as_u64(), Some(t));
    }
}
