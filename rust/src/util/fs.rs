//! Filesystem helpers: scoped temp dirs (tempfile crate unavailable),
//! recursive copy, directory size accounting (Table 2's storage
//! column measures real bytes on disk), and the durable write
//! primitives ([`durable_append`], [`durable_write_atomic`]) every
//! store mutation goes through.
//!
//! # Durability discipline
//!
//! The run store is the durable record, so its writers must survive a
//! crash at *any* instruction boundary:
//!
//! * [`durable_append`] writes the payload, fsyncs the file
//!   (`fdatasync`), and — when the append created the file — fsyncs
//!   the parent directory so the new name itself survives.
//! * [`durable_write_atomic`] stages into `<path>.tmp` in the same
//!   directory, fsyncs the temp file *before* the rename (so the
//!   rename can never install unflushed bytes), renames over the
//!   destination, then fsyncs the parent directory to persist the
//!   rename.
//!
//! Both consult [`crate::util::failpoint`] before each stage under a
//! caller-supplied site name (`store::append`, `store::manifest`,
//! `store::index`, `store::compact`), which is how the crash-matrix
//! test aborts between any two stages and proves `store fsck` recovers.
//! Transient injected `EINTR`s are retried in place.  Directory fsync
//! is a Unix concept; on other platforms that stage is a no-op.

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::util::failpoint::{self, Action};

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(label: &str) -> Result<TempDir> {
        let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "talp-pages-{}-{}-{}",
            label,
            std::process::id(),
            n
        ));
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating temp dir {}", path.display()))?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Leak the directory (keep it on disk), returning the path.
    pub fn into_path(mut self) -> PathBuf {
        std::mem::take(&mut self.path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        if !self.path.as_os_str().is_empty() {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

/// One fault-injectable write stage: consult `site::stage`, then run
/// the real syscall.  `Eintr` retries the consult (the rule's `@N`
/// or `:P` bound guarantees progress), `Delay` sleeps and retries,
/// `Crash` aborts the process, and the error actions fail the stage.
fn staged<T>(
    site: &str,
    stage: &str,
    mut op: impl FnMut(Action) -> std::io::Result<T>,
) -> std::io::Result<T> {
    loop {
        match failpoint::hit(site, stage) {
            Action::Crash => std::process::abort(),
            Action::Eintr => continue,
            Action::Delay(ms) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                continue;
            }
            act => return op(act),
        }
    }
}

/// Write `bytes` through the `site::write` failpoint: `Short` flushes
/// a torn half-payload to disk before failing, so recovery from a
/// partially-landed write is actually exercised.
fn staged_write(
    f: &mut std::fs::File,
    bytes: &[u8],
    site: &str,
) -> std::io::Result<()> {
    staged(site, "write", |act| match act {
        Action::Enospc => Err(failpoint::injected_error(
            &format!("{site}::write"),
            "no space left on device",
        )),
        Action::Short => {
            let _ = f.write_all(&bytes[..bytes.len() / 2]);
            let _ = f.sync_data();
            Err(failpoint::injected_error(
                &format!("{site}::write"),
                "short write (disk filled mid-write)",
            ))
        }
        _ => f.write_all(bytes),
    })
}

/// `fdatasync` through the `site::fsync` failpoint.
fn staged_fsync(f: &std::fs::File, site: &str) -> std::io::Result<()> {
    staged(site, "fsync", |act| match act {
        Action::Enospc | Action::Short => {
            Err(failpoint::injected_error(
                &format!("{site}::fsync"),
                "fsync failed",
            ))
        }
        _ => f.sync_data(),
    })
}

/// Fsync the directory containing `path` (through the
/// `site::dir_fsync` failpoint) so a just-created or just-renamed
/// name survives a crash.  Directory handles are only fsync-able on
/// Unix; elsewhere the stage still consults the failpoint but the
/// sync itself is skipped.
fn fsync_parent(path: &Path, site: &str) -> std::io::Result<()> {
    staged(site, "dir_fsync", |act| match act {
        Action::Enospc | Action::Short => {
            Err(failpoint::injected_error(
                &format!("{site}::dir_fsync"),
                "directory fsync failed",
            ))
        }
        _ => {
            #[cfg(unix)]
            {
                let dir = match path.parent() {
                    Some(d) if !d.as_os_str().is_empty() => d,
                    _ => Path::new("."),
                };
                std::fs::File::open(dir)?.sync_all()?;
            }
            #[cfg(not(unix))]
            let _ = path;
            Ok(())
        }
    })
}

/// Append `bytes` to `path` and make them durable before returning:
/// the file is opened in append mode (created if missing), written in
/// one `write_all`, fsync'd, and — when this call created the file —
/// the parent directory is fsync'd too so the new name survives a
/// crash.  `site` names the failpoints consulted (`<site>::write`,
/// `<site>::fsync`, `<site>::dir_fsync`).
pub fn durable_append(
    path: &Path,
    bytes: &[u8],
    site: &str,
) -> std::io::Result<()> {
    let created = !path.exists();
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    staged_write(&mut f, bytes, site)?;
    staged_fsync(&f, site)?;
    if created {
        fsync_parent(path, site)?;
    }
    Ok(())
}

/// Replace `path` with `bytes` atomically *and* durably: stage into
/// `<path>.tmp` (same directory, so the rename never crosses a
/// filesystem), fsync the temp file, rename it over `path`, fsync the
/// parent directory.  A crash before the rename leaves the old file
/// intact plus a `.tmp` orphan (`store fsck` removes it); a crash
/// after leaves the new file — never a torn destination.  `site`
/// names the failpoints (`<site>::{write,fsync,rename,dir_fsync}`).
pub fn durable_write_atomic(
    path: &Path,
    bytes: &[u8],
    site: &str,
) -> std::io::Result<()> {
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    let mut f = std::fs::File::create(&tmp)?;
    staged_write(&mut f, bytes, site)?;
    staged_fsync(&f, site)?;
    drop(f);
    staged(site, "rename", |act| match act {
        Action::Enospc | Action::Short => {
            Err(failpoint::injected_error(
                &format!("{site}::rename"),
                "rename failed",
            ))
        }
        _ => std::fs::rename(&tmp, path),
    })?;
    fsync_parent(path, site)
}

/// Recursively copy a directory tree.
pub fn copy_tree(src: &Path, dst: &Path) -> Result<u64> {
    let mut copied = 0u64;
    std::fs::create_dir_all(dst)?;
    for entry in std::fs::read_dir(src)
        .with_context(|| format!("reading {}", src.display()))?
    {
        let entry = entry?;
        let ty = entry.file_type()?;
        let to = dst.join(entry.file_name());
        if ty.is_dir() {
            copied += copy_tree(&entry.path(), &to)?;
        } else {
            std::fs::copy(entry.path(), &to)?;
            copied += 1;
        }
    }
    Ok(copied)
}

/// Total size in bytes of all files under `root`.
pub fn dir_size(root: &Path) -> u64 {
    let mut total = 0u64;
    let Ok(rd) = std::fs::read_dir(root) else {
        return 0;
    };
    for entry in rd.flatten() {
        let Ok(ty) = entry.file_type() else { continue };
        if ty.is_dir() {
            total += dir_size(&entry.path());
        } else if let Ok(md) = entry.metadata() {
            total += md.len();
        }
    }
    total
}

/// All files under `root` with the given extension, sorted for
/// deterministic iteration order.
pub fn files_with_ext(root: &Path, ext: &str) -> Vec<PathBuf> {
    let mut out = Vec::new();
    collect_ext(root, ext, &mut out);
    out.sort();
    out
}

fn collect_ext(root: &Path, ext: &str, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(root) else {
        return;
    };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_ext(&p, ext, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some(ext) {
            out.push(p);
        }
    }
}

/// Immediate subdirectories, sorted by name.
pub fn subdirs(root: &Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(root)
        .map(|rd| {
            rd.flatten()
                .filter(|e| e.path().is_dir())
                .map(|e| e.path())
                .collect()
        })
        .unwrap_or_default();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_removes() {
        let path;
        {
            let td = TempDir::new("test").unwrap();
            path = td.path().to_path_buf();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn copy_tree_and_sizes() {
        let td = TempDir::new("copy").unwrap();
        let src = td.path().join("src/a/b");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(src.join("x.json"), b"{}").unwrap();
        std::fs::write(td.path().join("src/top.json"), b"[1,2]").unwrap();
        let dst = td.path().join("dst");
        let n = copy_tree(&td.path().join("src"), &dst).unwrap();
        assert_eq!(n, 2);
        assert_eq!(dir_size(&dst), 7);
        let found = files_with_ext(&dst, "json");
        assert_eq!(found.len(), 2);
    }

    #[test]
    fn durable_append_creates_appends_and_leaves_no_residue() {
        let td = TempDir::new("durable-append").unwrap();
        let path = td.path().join("deep/dir/shard.jsonl");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        durable_append(&path, b"one\n", "test::append").unwrap();
        durable_append(&path, b"two\n", "test::append").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one\ntwo\n");
        assert_eq!(
            std::fs::read_dir(path.parent().unwrap()).unwrap().count(),
            1,
            "no temp files"
        );
    }

    #[test]
    fn durable_write_atomic_replaces_and_cleans_temp() {
        let td = TempDir::new("durable-atomic").unwrap();
        let path = td.path().join("manifest.json");
        durable_write_atomic(&path, b"{\"v\":1}", "test::atomic")
            .unwrap();
        durable_write_atomic(&path, b"{\"v\":2}", "test::atomic")
            .unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"v\":2}");
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(".tmp");
        assert!(
            !PathBuf::from(tmp).exists(),
            "temp staged file is renamed away"
        );
    }

    #[test]
    fn subdirs_sorted() {
        let td = TempDir::new("subdirs").unwrap();
        for d in ["zeta", "alpha", "mid"] {
            std::fs::create_dir(td.path().join(d)).unwrap();
        }
        std::fs::write(td.path().join("file.txt"), b"x").unwrap();
        let names: Vec<String> = subdirs(td.path())
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, ["alpha", "mid", "zeta"]);
    }
}
