//! Filesystem helpers: scoped temp dirs (tempfile crate unavailable),
//! recursive copy, and directory size accounting (Table 2's storage
//! column measures real bytes on disk).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(label: &str) -> Result<TempDir> {
        let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "talp-pages-{}-{}-{}",
            label,
            std::process::id(),
            n
        ));
        std::fs::create_dir_all(&path)
            .with_context(|| format!("creating temp dir {}", path.display()))?;
        Ok(TempDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Leak the directory (keep it on disk), returning the path.
    pub fn into_path(mut self) -> PathBuf {
        std::mem::take(&mut self.path)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        if !self.path.as_os_str().is_empty() {
            let _ = std::fs::remove_dir_all(&self.path);
        }
    }
}

/// Recursively copy a directory tree.
pub fn copy_tree(src: &Path, dst: &Path) -> Result<u64> {
    let mut copied = 0u64;
    std::fs::create_dir_all(dst)?;
    for entry in std::fs::read_dir(src)
        .with_context(|| format!("reading {}", src.display()))?
    {
        let entry = entry?;
        let ty = entry.file_type()?;
        let to = dst.join(entry.file_name());
        if ty.is_dir() {
            copied += copy_tree(&entry.path(), &to)?;
        } else {
            std::fs::copy(entry.path(), &to)?;
            copied += 1;
        }
    }
    Ok(copied)
}

/// Total size in bytes of all files under `root`.
pub fn dir_size(root: &Path) -> u64 {
    let mut total = 0u64;
    let Ok(rd) = std::fs::read_dir(root) else {
        return 0;
    };
    for entry in rd.flatten() {
        let Ok(ty) = entry.file_type() else { continue };
        if ty.is_dir() {
            total += dir_size(&entry.path());
        } else if let Ok(md) = entry.metadata() {
            total += md.len();
        }
    }
    total
}

/// All files under `root` with the given extension, sorted for
/// deterministic iteration order.
pub fn files_with_ext(root: &Path, ext: &str) -> Vec<PathBuf> {
    let mut out = Vec::new();
    collect_ext(root, ext, &mut out);
    out.sort();
    out
}

fn collect_ext(root: &Path, ext: &str, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(root) else {
        return;
    };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_ext(&p, ext, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some(ext) {
            out.push(p);
        }
    }
}

/// Immediate subdirectories, sorted by name.
pub fn subdirs(root: &Path) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(root)
        .map(|rd| {
            rd.flatten()
                .filter(|e| e.path().is_dir())
                .map(|e| e.path())
                .collect()
        })
        .unwrap_or_default();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_removes() {
        let path;
        {
            let td = TempDir::new("test").unwrap();
            path = td.path().to_path_buf();
            assert!(path.exists());
        }
        assert!(!path.exists());
    }

    #[test]
    fn copy_tree_and_sizes() {
        let td = TempDir::new("copy").unwrap();
        let src = td.path().join("src/a/b");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(src.join("x.json"), b"{}").unwrap();
        std::fs::write(td.path().join("src/top.json"), b"[1,2]").unwrap();
        let dst = td.path().join("dst");
        let n = copy_tree(&td.path().join("src"), &dst).unwrap();
        assert_eq!(n, 2);
        assert_eq!(dir_size(&dst), 7);
        let found = files_with_ext(&dst, "json");
        assert_eq!(found.len(), 2);
    }

    #[test]
    fn subdirs_sorted() {
        let td = TempDir::new("subdirs").unwrap();
        for d in ["zeta", "alpha", "mid"] {
            std::fs::create_dir(td.path().join(d)).unwrap();
        }
        std::fs::write(td.path().join("file.txt"), b"x").unwrap();
        let names: Vec<String> = subdirs(td.path())
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, ["alpha", "mid", "zeta"]);
    }
}
