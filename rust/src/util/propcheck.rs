//! Tiny property-test driver (proptest is unavailable offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded
//! inputs; on failure it reports the failing case index and seed so the
//! exact input can be replayed with `replay(seed, f)`.  Properties return
//! `Result<(), String>` so failures carry a description of the violated
//! invariant.

use super::rng::Rng;

pub const DEFAULT_CASES: u32 = 256;

/// Run `prop` over `cases` random inputs derived from a fixed master seed
/// (stable across runs — CI-reproducible by construction).
pub fn check<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut master = Rng::new(0x7a1b_0000 ^ fnv(name));
    for case in 0..cases {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F>(seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("replay of seed {seed:#x} failed: {msg}");
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 32, |rng| {
            n += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property 'alwaysfail' failed")]
    fn failing_property_panics_with_seed() {
        check("alwaysfail", 8, |_| Err("nope".into()));
    }

    #[test]
    fn fnv_distinguishes_names() {
        assert_ne!(fnv("a"), fnv("b"));
    }
}
