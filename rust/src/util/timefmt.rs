//! Unix-epoch <-> ISO-8601 conversion, hand-rolled (the `time` crate's
//! vendored copy can't be used offline — see Cargo.toml).
//!
//! TALP JSONs carry a `timestamp` (end of execution) and, when the
//! metadata wrapper ran, a `git.commit_timestamp`; TALP-Pages uses the
//! git timestamp when present (paper §Time-evolution plots).  All times
//! are UTC; the civil-from-days algorithm is Howard Hinnant's.

/// Convert unix seconds to "YYYY-MM-DDTHH:MM:SSZ".
pub fn to_iso8601(unix_secs: i64) -> String {
    let (y, m, d, hh, mm, ss) = civil(unix_secs);
    format!("{y:04}-{m:02}-{d:02}T{hh:02}:{mm:02}:{ss:02}Z")
}

/// Compact form used in artifact file names: "YYYY-MM-DDTHHMM".
pub fn to_filename_stamp(unix_secs: i64) -> String {
    let (y, m, d, hh, mm, _) = civil(unix_secs);
    format!("{y:04}-{m:02}-{d:02}T{hh:02}{mm:02}")
}

fn civil(unix_secs: i64) -> (i64, u32, u32, u32, u32, u32) {
    let days = unix_secs.div_euclid(86_400);
    let secs_of_day = unix_secs.rem_euclid(86_400) as u32;
    let (y, m, d) = civil_from_days(days);
    (
        y,
        m,
        d,
        secs_of_day / 3600,
        (secs_of_day / 60) % 60,
        secs_of_day % 60,
    )
}

/// Days since 1970-01-01 -> (year, month, day).  Hinnant's algorithm.
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097) as i64; // [0, 146096]
    let yoe = (doe - doe / 1460 + doe / 36524 - doe / 146096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

fn days_from_civil(y: i64, m: u32, d: u32) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = y.div_euclid(400);
    let yoe = y.rem_euclid(400);
    let mp = if m > 2 { m - 3 } else { m + 9 } as i64;
    let doy = (153 * mp + 2) / 5 + d as i64 - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    era * 146_097 + doe - 719_468
}

/// Parse "YYYY-MM-DDTHH:MM:SS" plus optional fractional seconds and an
/// optional zone (`Z`, `±HH:MM`, `±HHMM`, `±HH` — honored, not ignored)
/// back to unix seconds.  Returns None on malformed input, including
/// trailing junk after the seconds field.
pub fn from_iso8601(s: &str) -> Option<i64> {
    let b = s.as_bytes();
    if b.len() < 19 {
        return None;
    }
    let num = |range: std::ops::Range<usize>| -> Option<i64> {
        std::str::from_utf8(&b[range]).ok()?.parse().ok()
    };
    if b[4] != b'-' || b[7] != b'-' || b[10] != b'T' || b[13] != b':' || b[16] != b':' {
        return None;
    }
    let y = num(0..4)?;
    let m = num(5..7)? as u32;
    let d = num(8..10)? as u32;
    let hh = num(11..13)?;
    let mm = num(14..16)?;
    let ss = num(17..19)?;
    if !(1..=12).contains(&m) || !(1..=31).contains(&d) || hh > 23 || mm > 59 || ss > 60 {
        return None;
    }
    let base = days_from_civil(y, m, d) * 86_400 + hh * 3600 + mm * 60 + ss;

    // Optional fractional seconds, then an optional zone: `Z`,
    // `±HH:MM`, `±HHMM` or `±HH`.  CI variables routinely carry a
    // numeric offset (GitLab's CI_COMMIT_TIMESTAMP is the commit's
    // local time) — ignoring it would shift history points by hours,
    // so offsets are honored and any other trailing junk is an error
    // rather than a silent misread.
    let mut i = 19;
    if i < b.len() && b[i] == b'.' {
        let frac_start = i + 1;
        i = frac_start;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == frac_start {
            return None;
        }
    }
    let two = |a: u8, c: u8| -> Option<i64> {
        if a.is_ascii_digit() && c.is_ascii_digit() {
            Some(((a - b'0') as i64) * 10 + (c - b'0') as i64)
        } else {
            None
        }
    };
    match &b[i..] {
        [] | [b'Z'] | [b'z'] => Some(base),
        [sign @ (b'+' | b'-'), rest @ ..] => {
            let (oh, om) = match rest {
                [h1, h2, b':', m1, m2] => (two(*h1, *h2)?, two(*m1, *m2)?),
                [h1, h2, m1, m2] => (two(*h1, *h2)?, two(*m1, *m2)?),
                [h1, h2] => (two(*h1, *h2)?, 0),
                _ => return None,
            };
            if oh > 23 || om > 59 {
                return None;
            }
            let off = oh * 3600 + om * 60;
            Some(if *sign == b'+' { base - off } else { base + off })
        }
        _ => None,
    }
}

/// Current wall-clock unix seconds (only used for stamping real runs;
/// simulations carry their own synthetic clocks).  This is the one
/// sanctioned wall-clock read — `clippy.toml` disallows
/// `SystemTime::now` everywhere else.
#[allow(clippy::disallowed_methods)]
pub fn now_unix() -> i64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as i64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_is_1970() {
        assert_eq!(to_iso8601(0), "1970-01-01T00:00:00Z");
    }

    #[test]
    fn known_timestamps() {
        // 2024-07-15T12:34:56Z
        assert_eq!(to_iso8601(1_721_046_896), "2024-07-15T12:34:56Z");
        // leap-year Feb 29
        assert_eq!(to_iso8601(1_709_164_800), "2024-02-29T00:00:00Z");
    }

    #[test]
    fn roundtrip_many() {
        for &t in &[
            0i64,
            86_399,
            86_400,
            951_782_400,   // 2000-02-29
            1_721_046_896,
            4_102_444_800, // 2100-01-01
            -86_400,       // 1969-12-31
        ] {
            let s = to_iso8601(t);
            assert_eq!(from_iso8601(&s), Some(t), "{s}");
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        for s in ["", "2024", "2024-13-01T00:00:00Z", "2024-01-01 00:00:00",
                  "2024-01-01T25:00:00Z", "garbage-junk-data!",
                  "2024-01-01T00:00:00junk", "2024-01-01T00:00:00+1:00",
                  "2024-01-01T00:00:00.Z", "2024-01-01T00:00:00+99:00"] {
            assert_eq!(from_iso8601(s), None, "{s}");
        }
    }

    #[test]
    fn parse_honors_utc_offsets() {
        // GitLab's CI_COMMIT_TIMESTAMP carries the commit's local
        // offset; all of these name the same instant.
        let base = from_iso8601("2024-07-15T12:00:00Z").unwrap();
        for s in ["2024-07-15T12:00:00", "2024-07-15T13:00:00+01:00",
                  "2024-07-15T11:30:00-00:30", "2024-07-15T13:00:00+0100",
                  "2024-07-15T13:00:00+01", "2024-07-15T12:00:00.123Z",
                  "2024-07-15T05:00:00-07:00"] {
            assert_eq!(from_iso8601(s), Some(base), "{s}");
        }
    }

    #[test]
    fn filename_stamp_format() {
        assert_eq!(to_filename_stamp(1_721_046_896), "2024-07-15T1234");
    }

    #[test]
    fn ordering_is_monotonic() {
        let mut prev = String::new();
        for t in (0..2_000_000_000i64).step_by(97_777_777) {
            let s = to_iso8601(t);
            assert!(s > prev, "{s} vs {prev}");
            prev = s;
        }
    }
}
