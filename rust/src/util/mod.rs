//! Shared utilities: JSON codec, deterministic PRNG, statistics, time
//! formatting, filesystem helpers, property-test driver and bench
//! harness.  These exist in-repo because the offline image carries no
//! serde/rand/criterion/proptest (see Cargo.toml).

pub mod bench;
pub mod failpoint;
pub mod fs;
pub mod hash;
pub mod json;
pub mod par;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod text;
pub mod timefmt;
