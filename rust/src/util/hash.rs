//! FNV-1a hashing, shared by the CI runner's deterministic seeding and
//! the report engine's artifact-content cache keys.  Not cryptographic —
//! it only needs to be stable across runs and platforms and cheap over
//! a few-hundred-KB JSON artifact.

/// FNV-1a 64-bit over raw bytes.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a 64-bit over a string's UTF-8 bytes.
pub fn fnv1a_64_str(s: &str) -> u64 {
    fnv1a_64(s.as_bytes())
}

/// Fixed-width lowercase-hex rendering used in the cache file.
pub fn to_hex(h: u64) -> String {
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // FNV-1a reference values.
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64_str("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn content_sensitivity() {
        assert_ne!(fnv1a_64(b"{\"x\":1}"), fnv1a_64(b"{\"x\":2}"));
        assert_eq!(fnv1a_64(b"same"), fnv1a_64(b"same"));
    }

    #[test]
    fn hex_is_fixed_width() {
        assert_eq!(to_hex(0), "0000000000000000");
        assert_eq!(to_hex(0xabc).len(), 16);
    }
}
