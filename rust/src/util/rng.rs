//! Deterministic PRNG for the simulator and the property-test driver.
//!
//! xoshiro256** seeded through splitmix64 — the standard small-state
//! generator; implemented here because the `rand` crate is not available
//! offline.  Every simulator run takes an explicit seed so benches and
//! tests are exactly reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. one per simulated rank).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's method without bias correction is fine for sim noise,
        // but keep it exact for property tests: rejection sampling.
        if n == 0 {
            return 0;
        }
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi.saturating_sub(lo).max(1))
    }

    /// Standard normal via Box-Muller (cached second value omitted for
    /// simplicity; throughput is irrelevant here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            return (-2.0 * u1.ln()).sqrt()
                * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Lognormal multiplicative jitter with mean ~1 and the given sigma
    /// (in log space) — the simulator's run-to-run noise model.
    pub fn lognormal_jitter(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    pub fn bool_with_p(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random hex string (synthetic commit SHAs).
    pub fn hex(&mut self, len: usize) -> String {
        const HEX: &[u8] = b"0123456789abcdef";
        (0..len)
            .map(|_| HEX[self.below(16) as usize] as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_jitter_mean_near_one() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let m = (0..n).map(|_| r.lognormal_jitter(0.05)).sum::<f64>()
            / n as f64;
        assert!((m - 1.0).abs() < 0.01, "{m}");
    }

    #[test]
    fn hex_format() {
        let mut r = Rng::new(5);
        let h = r.hex(8);
        assert_eq!(h.len(), 8);
        assert!(h.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
