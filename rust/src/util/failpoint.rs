//! Deterministic fault injection for filesystem mutations.
//!
//! Every store write path (shard appends, manifest/index temp+rename,
//! lockfile create/release) and the serve refresh path consult a named
//! *failpoint* before each stage of the operation.  A failpoint is
//! identified as `site::stage` — e.g. `store::append::write`,
//! `store::manifest::rename` — and the full set is enumerated by
//! [`registered_points`] so the crash-matrix test can abort at every
//! one of them and prove recovery.
//!
//! # Activation
//!
//! Failpoints only exist when the crate is built with
//! `--features failpoints`; without the feature [`hit`] is an
//! `#[inline(always)]` constant `Action::None` and the consult folds
//! to nothing (the zero-cost requirement for release builds).  With
//! the feature, activation is still opt-in at runtime:
//!
//! * env: `TALP_FAILPOINTS='<spec>'` (read on first consult), seeded
//!   by `TALP_FAILPOINT_SEED=<u64>` (default 42) for probabilistic
//!   rules;
//! * CLI: `talp-pages --failpoints '<spec>' <command> ...`
//!   ([`configure`]), which overrides the environment.
//!
//! # Spec grammar
//!
//! A spec is `;`-separated `pattern=action` rules.  `pattern` is an
//! exact point name, a `prefix*` glob, or `*`.  `action` is one of
//!
//! * `crash` — [`std::process::abort`] at the point (a killed CI job);
//! * `enospc` — fail the stage with an injected I/O error;
//! * `short` — write half the payload, then fail (torn write);
//! * `eintr` — fail transiently; the durable helpers retry;
//! * `delay:<ms>` — sleep, then proceed (slow fsync).
//!
//! Each action takes an optional `@N` (fire only on the N-th consult
//! of that point; the default) or `:P` (fire with probability `P` on
//! every consult, drawn from the seeded PRNG).  Without either, a rule
//! fires on the point's first consult only — so `store::append::write=eintr`
//! injects exactly one transient failure and the retry succeeds.  The
//! first rule that *fires* wins; rules that match but do not fire fall
//! through, so `*=eintr:0.05;*=delay:10:0.02` is a layered chaos spec.
//!
//! Examples:
//!
//! ```text
//! TALP_FAILPOINTS='store::manifest::rename=crash'       # abort between write and rename
//! TALP_FAILPOINTS='store::append::write=short'          # torn shard append
//! TALP_FAILPOINTS='serve::refresh=enospc@2'             # second refresh fails
//! TALP_FAILPOINTS='*=eintr:0.05' TALP_FAILPOINT_SEED=7  # seeded background noise
//! ```

/// What an activated failpoint injects at one control point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    /// Proceed normally.
    None,
    /// Abort the process on the spot — simulates a CI job killed at
    /// this exact point (no destructors, no flushes).
    Crash,
    /// Fail the stage with an injected "no space left on device"
    /// I/O error.
    Enospc,
    /// Fail transiently (an interrupted syscall); callers retry.
    Eintr,
    /// Write only half the payload, then fail — a torn write.
    Short,
    /// Sleep this many milliseconds, then proceed.
    Delay(u64),
}

/// Every failpoint the store and serve paths consult, for matrix
/// enumeration.  `dir_fsync` points fire after rename (or after an
/// append that created the file); `store::lock::*` bracket lockfile
/// create/release; `serve::refresh` guards the monitor's snapshot
/// refresh (exercised by the serve degraded-mode test, not the store
/// crash matrix).
pub const REGISTERED_POINTS: &[&str] = &[
    "store::append::write",
    "store::append::fsync",
    "store::append::dir_fsync",
    "store::manifest::write",
    "store::manifest::fsync",
    "store::manifest::rename",
    "store::manifest::dir_fsync",
    "store::index::write",
    "store::index::fsync",
    "store::index::rename",
    "store::index::dir_fsync",
    "store::compact::write",
    "store::compact::fsync",
    "store::compact::rename",
    "store::compact::dir_fsync",
    "store::lock::create",
    "store::lock::release",
    "serve::refresh",
];

/// All registered failpoint names.
pub fn registered_points() -> &'static [&'static str] {
    REGISTERED_POINTS
}

/// Is fault injection compiled into this build?
pub fn enabled() -> bool {
    cfg!(feature = "failpoints")
}

/// The error an injected `enospc`/`short` stage fails with.  Public so
/// tests can assert on the marker.
pub fn injected_error(point: &str, what: &str) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::Other,
        format!("injected fault at {point}: {what}"),
    )
}

#[cfg(not(feature = "failpoints"))]
mod imp {
    /// No-op consult: compiles to a constant, so every call site folds
    /// to the plain syscall path.
    #[inline(always)]
    pub fn hit(_site: &str, _stage: &str) -> super::Action {
        super::Action::None
    }

    pub fn configure(_spec: &str) -> anyhow::Result<()> {
        anyhow::bail!(
            "this build has no fault-injection support; rebuild with \
             `--features failpoints` to use --failpoints/TALP_FAILPOINTS"
        )
    }

    /// Consults so far for one point (always 0 without the feature).
    pub fn hits(_point: &str) -> u64 {
        0
    }
}

#[cfg(feature = "failpoints")]
mod imp {
    use std::collections::HashMap;
    use std::sync::Mutex;

    use anyhow::{bail, Context, Result};

    use super::Action;
    use crate::util::rng::Rng;

    /// One `pattern=action` rule.
    struct Rule {
        /// Exact point name, or a prefix when `glob` is set (`*` is an
        /// empty prefix).
        prefix: String,
        glob: bool,
        action: Action,
        /// Fire only on the N-th consult of the point (1-based).
        nth: Option<u64>,
        /// Fire with this probability on every consult.
        prob: Option<f64>,
    }

    impl Rule {
        fn matches(&self, point: &str) -> bool {
            if self.glob {
                point.starts_with(self.prefix.as_str())
            } else {
                point == self.prefix
            }
        }
    }

    struct State {
        rules: Vec<Rule>,
        /// Consults per point name.
        counters: HashMap<String, u64>,
        rng: Rng,
    }

    static STATE: Mutex<Option<State>> = Mutex::new(None);

    fn parse_spec(spec: &str) -> Result<Vec<Rule>> {
        let mut rules = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (pattern, action) = part.split_once('=').with_context(
                || format!("failpoint rule `{part}` has no `=action`"),
            )?;
            let (pattern, action) = (pattern.trim(), action.trim());
            let (prefix, glob) = match pattern.strip_suffix('*') {
                Some(p) => (p.to_string(), true),
                None => (pattern.to_string(), false),
            };
            let mut fields = action.split(':');
            let head = fields.next().unwrap_or_default();
            let (kind, nth) = match head.split_once('@') {
                Some((k, n)) => (
                    k,
                    Some(n.parse::<u64>().with_context(|| {
                        format!("bad @N in failpoint rule `{part}`")
                    })?),
                ),
                None => (head, None),
            };
            let mut numbers: Vec<f64> = Vec::new();
            for f in fields {
                numbers.push(f.parse::<f64>().with_context(|| {
                    format!("bad number `{f}` in failpoint rule `{part}`")
                })?);
            }
            let (action, prob) = match kind {
                "crash" => (Action::Crash, numbers.first().copied()),
                "enospc" => (Action::Enospc, numbers.first().copied()),
                "eintr" => (Action::Eintr, numbers.first().copied()),
                "short" => (Action::Short, numbers.first().copied()),
                "delay" => {
                    let ms = numbers.first().copied().with_context(
                        || format!("`{part}` needs delay:<ms>"),
                    )?;
                    (Action::Delay(ms as u64), numbers.get(1).copied())
                }
                other => bail!(
                    "unknown failpoint action `{other}` in `{part}` \
                     (crash, enospc, eintr, short, delay:<ms>)"
                ),
            };
            if let Some(p) = prob {
                if !(0.0..=1.0).contains(&p) {
                    bail!("probability {p} out of [0,1] in `{part}`");
                }
            }
            rules.push(Rule { prefix, glob, action, nth, prob });
        }
        Ok(rules)
    }

    fn seed_from_env() -> u64 {
        std::env::var("TALP_FAILPOINT_SEED")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(42)
    }

    fn state_from_env() -> State {
        let rules = match std::env::var("TALP_FAILPOINTS") {
            Ok(spec) => parse_spec(&spec).unwrap_or_else(|e| {
                // A test-only feature fed a broken spec must fail the
                // run loudly, not silently inject nothing.
                panic!("TALP_FAILPOINTS: {e:#}")
            }),
            Err(_) => Vec::new(),
        };
        State {
            rules,
            counters: HashMap::new(),
            rng: Rng::new(seed_from_env()),
        }
    }

    /// Install `spec`, replacing any env-derived configuration and
    /// resetting all counters (the CLI `--failpoints` path).
    pub fn configure(spec: &str) -> Result<()> {
        let rules = parse_spec(spec)?;
        let mut g =
            STATE.lock().unwrap_or_else(|e| e.into_inner());
        *g = Some(State {
            rules,
            counters: HashMap::new(),
            rng: Rng::new(seed_from_env()),
        });
        Ok(())
    }

    /// Consult the failpoint `site::stage`: counts the consult, then
    /// returns the action of the first rule that fires.
    pub fn hit(site: &str, stage: &str) -> Action {
        let mut g =
            STATE.lock().unwrap_or_else(|e| e.into_inner());
        let st = g.get_or_insert_with(state_from_env);
        if st.rules.is_empty() {
            return Action::None;
        }
        let point = format!("{site}::{stage}");
        let c = st.counters.entry(point.clone()).or_insert(0);
        *c += 1;
        let n = *c;
        for i in 0..st.rules.len() {
            if !st.rules[i].matches(&point) {
                continue;
            }
            let fires = match (st.rules[i].nth, st.rules[i].prob) {
                (Some(k), _) => n == k,
                (None, Some(p)) => st.rng.f64() < p,
                (None, None) => n == 1,
            };
            if fires {
                return st.rules[i].action;
            }
        }
        Action::None
    }

    /// Consults so far for one point (diagnostic/test hook).
    pub fn hits(point: &str) -> u64 {
        let g = STATE.lock().unwrap_or_else(|e| e.into_inner());
        g.as_ref()
            .and_then(|st| st.counters.get(point).copied())
            .unwrap_or(0)
    }
}

pub use imp::{configure, hit, hits};

/// Consult a non-write control point (lock create/release, serve
/// refresh): `Crash` aborts, `Enospc`/`Short` return the injected
/// error, `Eintr`/`Delay` retry the consult.  Without the `failpoints`
/// feature this inlines to `Ok(())`.
#[inline]
pub fn check(site: &str, stage: &str) -> std::io::Result<()> {
    loop {
        match hit(site, stage) {
            Action::None => return Ok(()),
            Action::Crash => std::process::abort(),
            Action::Eintr => continue,
            Action::Delay(ms) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                continue;
            }
            Action::Enospc | Action::Short => {
                return Err(injected_error(
                    &format!("{site}::{stage}"),
                    "injected failure",
                ))
            }
        }
    }
}

#[cfg(all(test, feature = "failpoints"))]
mod tests {
    use super::*;

    // `configure` replaces global state, so the spec-behavior tests
    // run under one lock to avoid cross-test interference.
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn every_registered_point_is_well_formed() {
        for p in registered_points() {
            let parts: Vec<&str> = p.split("::").collect();
            assert!(parts.len() >= 2, "{p} needs site::stage");
            assert!(parts.iter().all(|s| !s.is_empty()), "{p}");
        }
        // No duplicates.
        let set: std::collections::HashSet<_> =
            registered_points().iter().collect();
        assert_eq!(set.len(), registered_points().len());
    }

    #[test]
    fn default_rule_fires_on_first_consult_only() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        configure("store::append::write=enospc").unwrap();
        assert_eq!(
            hit("store::append", "write"),
            Action::Enospc,
            "first consult fires"
        );
        assert_eq!(hit("store::append", "write"), Action::None);
        assert_eq!(hit("store::append", "fsync"), Action::None);
        configure("").unwrap();
    }

    #[test]
    fn nth_glob_and_fallthrough() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        configure("store::manifest::*=delay:5@2;*=eintr@3").unwrap();
        assert_eq!(hit("store::manifest", "rename"), Action::None);
        assert_eq!(
            hit("store::manifest", "rename"),
            Action::Delay(5),
            "second consult hits the glob rule"
        );
        // Third consult: the glob rule matches but no longer fires,
        // so the catch-all @3 rule gets its turn.
        assert_eq!(hit("store::manifest", "rename"), Action::Eintr);
        assert_eq!(hits("store::manifest::rename"), 3);
        configure("").unwrap();
    }

    #[test]
    fn bad_specs_are_rejected() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        for bad in [
            "store::append::write",          // no action
            "x=explode",                     // unknown action
            "x=crash@many",                  // bad @N
            "x=delay",                       // delay without ms
            "x=enospc:1.5",                  // probability out of range
        ] {
            assert!(configure(bad).is_err(), "{bad} should be rejected");
        }
        configure("").unwrap();
    }

    #[test]
    fn check_retries_transients_and_surfaces_errors() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        configure("store::lock::create=eintr").unwrap();
        assert!(check("store::lock", "create").is_ok(), "retried past EINTR");
        configure("store::lock::release=enospc").unwrap();
        let err = check("store::lock", "release").unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        configure("").unwrap();
    }
}
