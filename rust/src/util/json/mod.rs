//! JSON for TALP-Pages: one streaming core, two APIs.
//!
//! serde_json is unavailable in this offline image (see Cargo.toml
//! note), so the TALP JSON schema, the run store shards, the metrics
//! cache and the CI metadata all go through this module.  It implements
//! RFC 8259 minus some laxities: no `\u` surrogate-pair validation
//! beyond replacement, numbers are f64 (TALP times are ns-as-integers
//! < 2^53, safe in f64), object key order is preserved (Vec-backed) so
//! reports render deterministically.
//!
//! Two layers share one grammar and one formatter:
//!
//! * **Streaming** ([`JsonReader`] in [`reader`], [`JsonWriter`] in
//!   [`writer`]): a pull/event parser over `&[u8]` with zero-copy
//!   `Cow<str>` strings, and a direct-to-buffer serializer.  The hot
//!   artifact → store → report path decodes and encodes through these
//!   without materializing a tree.
//! * **Tree** ([`Json`]): the Vec-backed value model for tests,
//!   configuration files and low-frequency callers.  `Json::parse` is
//!   built on the reader and `to_string_*` on the writer, so the two
//!   layers are byte-identical by construction.

pub mod reader;
pub mod writer;

pub use reader::{Event, JsonReader};
pub use writer::JsonWriter;

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset and human context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- constructors ----------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---------- accessors ----------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(pairs) => pairs.iter_mut().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Insert or replace a key in an object; panics on non-objects
    /// (programming error, not data error).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(pairs) => {
                if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Append a field whose key is known not to be present — the
    /// builder fast path that skips [`Json::set`]'s replace scan.
    /// Debug builds assert uniqueness; release builds trust the caller
    /// (the crate's serializers only pass literal or pre-deduplicated
    /// keys).  Panics on non-objects, like `set`.
    pub fn push_field(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(pairs) => {
                debug_assert!(
                    pairs.iter().all(|(k, _)| k != key),
                    "push_field: duplicate key {key}"
                );
                pairs.push((key.to_string(), value));
            }
            _ => panic!("Json::push_field on non-object"),
        }
    }

    /// Path lookup: `j.at(&["region", "useful_time"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: f64 field lookup with default.
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    // ---------- serialization (via the streaming writer) ----------
    pub fn to_string_pretty(&self) -> String {
        let mut w = JsonWriter::with_capacity(1024, true);
        w.value(self);
        w.newline();
        w.into_string()
    }

    pub fn to_string_compact(&self) -> String {
        let mut w = JsonWriter::with_capacity(256, false);
        w.value(self);
        w.into_string()
    }

    // ---------- parsing (via the streaming reader) ----------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        Json::from_slice(text.as_bytes())
    }

    /// Parse raw bytes.  UTF-8 is validated only inside string
    /// literals (everything else in JSON is ASCII), so callers with a
    /// fresh `Vec<u8>` skip the whole-buffer validation copy.
    pub fn from_slice(bytes: &[u8]) -> Result<Json, JsonError> {
        let mut r = JsonReader::new(bytes);
        let first = r.next()?;
        let v = tree_from_event(&mut r, first)?;
        r.finish()?;
        Ok(v)
    }
}

/// Build a tree value from `ev` (just pulled from `r`), consuming the
/// rest of the value's events.
fn tree_from_event(
    r: &mut JsonReader<'_>,
    ev: Event<'_>,
) -> Result<Json, JsonError> {
    Ok(match ev {
        Event::Null => Json::Null,
        Event::Bool(b) => Json::Bool(b),
        Event::Num(n) => Json::Num(n),
        Event::Str(s) => Json::Str(s.into_owned()),
        Event::ArrStart => {
            let mut items = Vec::new();
            loop {
                match r.next()? {
                    Event::ArrEnd => break,
                    ev => items.push(tree_from_event(r, ev)?),
                }
            }
            Json::Arr(items)
        }
        Event::ObjStart => {
            let mut pairs: Vec<(String, Json)> = Vec::new();
            loop {
                match r.next()? {
                    Event::ObjEnd => break,
                    Event::Key(k) => {
                        let key = k.into_owned();
                        let ev = r.next()?;
                        pairs.push((key, tree_from_event(r, ev)?));
                    }
                    _ => unreachable!("objects yield Key/ObjEnd events"),
                }
            }
            Json::Obj(pairs)
        }
        Event::ArrEnd | Event::ObjEnd | Event::Key(_) => {
            unreachable!("container end/key in value position")
        }
    })
}

/// Amortized-O(1) repeated field lookup over one object's pairs.
///
/// [`Json::get`] is a linear scan — fine for one lookup, quadratic for
/// schema decoders that read every field of wide objects (the profile
/// hotspot in `RunData::from_json`'s per-process reads and
/// `RunMetrics::from_json`'s per-region reads).  The cursor remembers
/// where the last hit was and scans onward from there first, so fields
/// read in serialization order cost one comparison each; out-of-order
/// reads fall back to a full wrap-around scan.  Key order in the
/// underlying object is never changed.
pub struct FieldCursor<'a> {
    pairs: &'a [(String, Json)],
    next: usize,
}

impl<'a> FieldCursor<'a> {
    /// Cursor over `j`'s fields (empty for non-objects, so lookups
    /// simply miss — the same shape `Json::get` gives on non-objects).
    pub fn new(j: &'a Json) -> FieldCursor<'a> {
        FieldCursor { pairs: j.as_obj().unwrap_or(&[]), next: 0 }
    }

    /// Find `key`, scanning from just past the previous hit.
    pub fn get(&mut self, key: &str) -> Option<&'a Json> {
        let n = self.pairs.len();
        for off in 0..n {
            let mut i = self.next + off;
            if i >= n {
                i -= n;
            }
            if self.pairs[i].0 == key {
                self.next = i + 1;
                if self.next == n {
                    self.next = 0;
                }
                return Some(&self.pairs[i].1);
            }
        }
        None
    }

    pub fn num_or(&mut self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn u64_or(&mut self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn str_or(&mut self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }
}

/// Recover a [`JsonError`] byte offset from an `anyhow` chain.
///
/// The vendored `anyhow` flattens causes into strings (no downcast),
/// so this searches each chain message for the stable Display prefix
/// `"json error at byte N"` — including messages that *embedded* a
/// stringified `JsonError` (e.g. `policy x: json error at byte 7: ..`).
/// Used by the `check` subsystem to attach spans to diagnostics.
pub fn error_offset(err: &anyhow::Error) -> Option<usize> {
    const TAG: &str = "json error at byte ";
    err.chain().find_map(|msg| {
        let pos = msg.find(TAG)?;
        let rest = &msg[pos + TAG.len()..];
        let end = rest
            .find(|c: char| !c.is_ascii_digit())
            .unwrap_or(rest.len());
        rest[..end].parse().ok()
    })
}

/// Sort an object's keys recursively (for canonical comparisons in tests).
pub fn canonicalize(j: &Json) -> Json {
    match j {
        Json::Obj(pairs) => {
            let map: BTreeMap<String, Json> = pairs
                .iter()
                .map(|(k, v)| (k.clone(), canonicalize(v)))
                .collect();
            Json::Obj(map.into_iter().collect())
        }
        Json::Arr(items) => Json::Arr(items.iter().map(canonicalize).collect()),
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_types() {
        for text in [
            "null",
            "true",
            "false",
            "0",
            "-12",
            "3.5",
            "1e3",
            "\"hi\"",
            "[]",
            "{}",
            "[1,2,3]",
            "{\"a\":1,\"b\":[true,null]}",
        ] {
            let v = Json::parse(text).unwrap();
            let re = Json::parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, re, "{text}");
        }
    }

    #[test]
    fn parses_nested_and_preserves_order() {
        let v = Json::parse(r#"{"z":1,"a":{"k":[1,2,{"x":"y"}]}}"#).unwrap();
        let keys: Vec<&str> =
            v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a"]);
        assert_eq!(
            v.at(&["a", "k"]).unwrap().as_arr().unwrap().len(),
            3
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "line\n\ttab \"quote\" back\\slash \u{263a}";
        let j = Json::Str(s.to_string());
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed.as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""A☺""#).unwrap().as_str().unwrap(),
            "A\u{263a}"
        );
        assert_eq!(
            Json::parse(r#""😀""#).unwrap().as_str().unwrap(),
            "\u{1f600}"
        );
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\"}", "nul", "01x", "\"abc", "[1] junk"] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn integers_render_without_fraction() {
        let j = Json::Num(1234567890.0);
        assert_eq!(j.to_string_compact(), "1234567890");
        let j = Json::Num(0.25);
        assert_eq!(j.to_string_compact(), "0.25");
    }

    #[test]
    fn set_and_get() {
        let mut o = Json::obj();
        o.set("x", Json::Num(1.0));
        o.set("x", Json::Num(2.0));
        o.set("y", Json::Str("v".into()));
        assert_eq!(o.num_or("x", 0.0), 2.0);
        assert_eq!(o.str_or("y", ""), "v");
        assert_eq!(o.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn push_field_appends_without_scanning() {
        let mut o = Json::obj();
        o.push_field("a", Json::Num(1.0));
        o.push_field("b", Json::Num(2.0));
        let keys: Vec<&str> =
            o.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["a", "b"]);
        assert_eq!(o.num_or("b", 0.0), 2.0);
    }

    #[test]
    fn pretty_output_parses_back() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":null}}"#).unwrap();
        let pretty = v.to_string_pretty();
        assert!(pretty.contains("\n"));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn canonicalize_sorts_keys() {
        let a = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"b":1}"#).unwrap();
        assert_ne!(a, b);
        assert_eq!(canonicalize(&a), canonicalize(&b));
    }

    #[test]
    fn large_integer_precision_preserved() {
        // ns timestamps fit in f64's 2^53 integer range.
        let t = 1_720_000_000_000_000_000u64 / 1000; // us precision
        let j = Json::Num(t as f64);
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap().as_u64(), Some(t));
    }

    #[test]
    fn from_slice_matches_parse() {
        let text = r#"{"a":[1,2.5],"s":"x\ny","n":null}"#;
        assert_eq!(
            Json::from_slice(text.as_bytes()).unwrap(),
            Json::parse(text).unwrap()
        );
        // Invalid UTF-8 inside a string is a JsonError, not a panic.
        let mut bad = b"{\"k\":\"a".to_vec();
        bad.push(0xfe);
        bad.extend_from_slice(b"\"}");
        assert!(Json::from_slice(&bad).is_err());
    }

    #[test]
    fn error_offset_recovers_from_chain_and_embedded_text() {
        let e = Json::parse("{\"a\": nope}").unwrap_err();
        let off = e.offset;
        // Direct conversion keeps the offset.
        let any = anyhow::Error::from(e.clone());
        assert_eq!(error_offset(&any), Some(off));
        // Context layers on top do not hide it.
        use anyhow::Context;
        let wrapped = Err::<(), _>(any)
            .context("parsing fixture x")
            .unwrap_err();
        assert_eq!(error_offset(&wrapped), Some(off));
        // A stringified JsonError inside a message still yields it.
        let embedded = anyhow::anyhow!("policy p.json: {e}");
        assert_eq!(error_offset(&embedded), Some(off));
        // No tag anywhere -> None.
        assert_eq!(error_offset(&anyhow::anyhow!("plain failure")), None);
    }

    #[test]
    fn field_cursor_in_order_and_wraparound() {
        let j = Json::parse(r#"{"a":1,"b":"two","c":3,"d":4}"#).unwrap();
        let mut cur = FieldCursor::new(&j);
        // In serialization order: each hit is one comparison.
        assert_eq!(cur.num_or("a", 0.0), 1.0);
        assert_eq!(cur.str_or("b", ""), "two");
        assert_eq!(cur.num_or("c", 0.0), 3.0);
        // Out of order: wrap-around scan still finds earlier keys.
        assert_eq!(cur.num_or("a", 0.0), 1.0);
        assert_eq!(cur.num_or("d", 0.0), 4.0);
        assert_eq!(cur.get("nope"), None);
        assert_eq!(cur.num_or("missing", 9.5), 9.5);
        // Non-objects miss everything instead of panicking.
        let mut none = FieldCursor::new(&Json::Null);
        assert_eq!(none.get("a"), None);
        assert_eq!(none.u64_or("a", 7), 7);
    }
}
