//! Pull-based JSON event reader — the read half of the streaming core.
//!
//! [`JsonReader`] walks a raw `&[u8]` buffer and yields a flat stream
//! of [`Event`]s (`ObjStart`, `Key`, `Num`, ..., `ObjEnd`) without
//! allocating a tree.  Strings are [`Cow`]s: the common case (no escape
//! sequences) borrows straight from the input buffer; escapes decode
//! into an owned `String` only when present.  Because the input is
//! bytes rather than `&str`, the reader validates UTF-8 itself — but
//! only inside string literals, where non-ASCII bytes can legally
//! appear — so hot callers skip the whole-buffer `String::from_utf8`
//! copy/validate pass entirely.
//!
//! Grammar and laxities are exactly those of the historical tree
//! parser (the tree API's `Json::parse` is now built on this reader):
//! numbers parse as `f64`, `\u` escapes handle surrogate pairs with
//! U+FFFD replacement for lone high surrogates, object key order is
//! the event order.  Errors are [`JsonError`]s carrying the byte
//! offset where parsing stopped.

use std::borrow::Cow;

use super::JsonError;

/// One parse event.  `Str` covers string values; object keys arrive as
/// `Key` (always followed by the field's value events).
#[derive(Debug, Clone, PartialEq)]
pub enum Event<'a> {
    Null,
    Bool(bool),
    Num(f64),
    Str(Cow<'a, str>),
    ArrStart,
    ArrEnd,
    ObjStart,
    Key(Cow<'a, str>),
    ObjEnd,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Frame {
    Arr,
    Obj,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Expect {
    /// A value: document start, after a key's `:`, after `,` in an array.
    Value,
    /// Right after `[`: first element or an immediate `]`.
    ValueOrArrEnd,
    /// Right after `{`: first key or an immediate `}`.
    KeyOrObjEnd,
    /// After a completed value inside a container.  (A `,` here leads
    /// straight to the next value/key; trailing commas are invalid,
    /// matching the tree parser.)
    CommaOrEnd,
    /// The document value is complete; only [`JsonReader::finish`] is
    /// meaningful now.
    Done,
}

/// Streaming pull parser over a byte slice.
#[derive(Debug)]
pub struct JsonReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    stack: Vec<Frame>,
    expect: Expect,
}

impl<'a> JsonReader<'a> {
    pub fn new(bytes: &'a [u8]) -> JsonReader<'a> {
        JsonReader { bytes, pos: 0, stack: Vec::new(), expect: Expect::Value }
    }

    /// Byte offset of the next unread input (for error attribution by
    /// callers layering schema errors on top of parse position).
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Nesting depth of open containers.
    pub fn depth(&self) -> usize {
        self.stack.len()
    }

    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn err_at(&self, offset: usize, msg: &str) -> JsonError {
        JsonError { offset, message: msg.to_string() }
    }

    fn utf8_err(&self, start: usize, e: std::str::Utf8Error) -> JsonError {
        self.err_at(start + e.valid_up_to(), "invalid utf-8 in string")
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    /// Pull the next event.  Calling past the end of the document (or
    /// after an error) is itself an error, never a panic.
    ///
    /// Not an `Iterator`: the `Result` is load-bearing (errors carry
    /// byte offsets and poison the stream) and callers drive the
    /// reader from schema decoders, not `for` loops.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Result<Event<'a>, JsonError> {
        self.skip_ws();
        match self.expect {
            Expect::Done => Err(self.err("no value expected here")),
            Expect::Value => self.value_event(),
            Expect::ValueOrArrEnd => {
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    Ok(self.pop(Frame::Arr))
                } else {
                    self.value_event()
                }
            }
            Expect::KeyOrObjEnd => {
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    Ok(self.pop(Frame::Obj))
                } else {
                    self.key_event()
                }
            }
            Expect::CommaOrEnd => match (self.stack.last(), self.peek()) {
                (Some(Frame::Arr), Some(b',')) => {
                    self.pos += 1;
                    self.skip_ws();
                    self.value_event()
                }
                (Some(Frame::Arr), Some(b']')) => {
                    self.pos += 1;
                    Ok(self.pop(Frame::Arr))
                }
                (Some(Frame::Arr), _) => Err(self.err("expected ',' or ']'")),
                (Some(Frame::Obj), Some(b',')) => {
                    self.pos += 1;
                    self.skip_ws();
                    self.key_event()
                }
                (Some(Frame::Obj), Some(b'}')) => {
                    self.pos += 1;
                    Ok(self.pop(Frame::Obj))
                }
                (Some(Frame::Obj), _) => Err(self.err("expected ',' or '}'")),
                (None, _) => unreachable!(
                    "CommaOrEnd only occurs inside a container"
                ),
            },
        }
    }

    /// Verify the document is complete with no trailing data (the
    /// tree parser's exact end-of-input rule).
    pub fn finish(&mut self) -> Result<(), JsonError> {
        if self.expect != Expect::Done {
            return Err(self.err("document incomplete"));
        }
        self.skip_ws();
        if self.pos != self.bytes.len() {
            return Err(self.err("trailing data after document"));
        }
        Ok(())
    }

    /// Consume one complete value (scalar or whole container) from
    /// value position and discard it — how schema decoders skip
    /// unknown fields without building a tree.
    pub fn skip_value(&mut self) -> Result<(), JsonError> {
        match self.next()? {
            Event::ArrStart | Event::ObjStart => self.skip_rest(1),
            Event::Key(_) => unreachable!("skip_value in key position"),
            _scalar => Ok(()),
        }
    }

    /// Consume the remainder of a container whose start event the
    /// caller already pulled — the "wrong container type, treat the
    /// field as absent" path in schema decoders.
    pub fn skip_value_rest(&mut self) -> Result<(), JsonError> {
        self.skip_rest(1)
    }

    /// Consume events until `depth` open containers have closed.
    fn skip_rest(&mut self, mut depth: usize) -> Result<(), JsonError> {
        while depth > 0 {
            match self.next()? {
                Event::ArrStart | Event::ObjStart => depth += 1,
                Event::ArrEnd | Event::ObjEnd => depth -= 1,
                _ => {}
            }
        }
        Ok(())
    }

    // ---------- value-position coercion helpers ----------
    //
    // Schema decoders sit right after a `Key` event and want "the
    // field as an f64/u64/str, or nothing".  These mirror the tree
    // accessors (`Json::as_f64`/`as_u64`/`as_str`): a present but
    // wrong-typed value is consumed whole and coerces to `None`, never
    // an error — so streaming decoders accept and reject exactly the
    // same documents as their tree counterparts.

    /// Pull one value; `Some(n)` for a number, `None` otherwise.
    pub fn f64_opt(&mut self) -> Result<Option<f64>, JsonError> {
        match self.next()? {
            Event::Num(n) => Ok(Some(n)),
            Event::ArrStart | Event::ObjStart => {
                self.skip_rest(1)?;
                Ok(None)
            }
            _ => Ok(None),
        }
    }

    /// Pull one value; `Some(n)` for a non-negative integral number
    /// (the tree `as_u64` rule), `None` otherwise.
    pub fn u64_opt(&mut self) -> Result<Option<u64>, JsonError> {
        Ok(self
            .f64_opt()?
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| n as u64))
    }

    /// Pull one value; `Some(s)` for a string, `None` otherwise.
    pub fn str_opt(&mut self) -> Result<Option<Cow<'a, str>>, JsonError> {
        match self.next()? {
            Event::Str(s) => Ok(Some(s)),
            Event::ArrStart | Event::ObjStart => {
                self.skip_rest(1)?;
                Ok(None)
            }
            _ => Ok(None),
        }
    }

    /// Close `frame` and emit its end event.
    fn pop(&mut self, frame: Frame) -> Event<'a> {
        let popped = self.stack.pop();
        debug_assert_eq!(popped, Some(frame));
        self.expect = if self.stack.is_empty() {
            Expect::Done
        } else {
            Expect::CommaOrEnd
        };
        match frame {
            Frame::Arr => Event::ArrEnd,
            Frame::Obj => Event::ObjEnd,
        }
    }

    fn value_event(&mut self) -> Result<Event<'a>, JsonError> {
        let ev = match self.peek() {
            Some(b'n') => {
                self.literal(b"null")?;
                Event::Null
            }
            Some(b't') => {
                self.literal(b"true")?;
                Event::Bool(true)
            }
            Some(b'f') => {
                self.literal(b"false")?;
                Event::Bool(false)
            }
            Some(b'"') => Event::Str(self.string()?),
            Some(b'[') => {
                self.pos += 1;
                self.stack.push(Frame::Arr);
                self.expect = Expect::ValueOrArrEnd;
                return Ok(Event::ArrStart);
            }
            Some(b'{') => {
                self.pos += 1;
                self.stack.push(Frame::Obj);
                self.expect = Expect::KeyOrObjEnd;
                return Ok(Event::ObjStart);
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                Event::Num(self.number()?)
            }
            Some(_) => return Err(self.err("unexpected character")),
            None => return Err(self.err("unexpected end of input")),
        };
        self.expect = if self.stack.is_empty() {
            Expect::Done
        } else {
            Expect::CommaOrEnd
        };
        Ok(ev)
    }

    fn key_event(&mut self) -> Result<Event<'a>, JsonError> {
        let key = self.string()?;
        self.skip_ws();
        self.expect_byte(b':')?;
        self.expect = Expect::Value;
        Ok(Event::Key(key))
    }

    fn literal(&mut self, lit: &[u8]) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(lit) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!(
                "expected '{}'",
                std::str::from_utf8(lit).unwrap_or("literal")
            )))
        }
    }

    /// Parse a string literal.  Fast path: no escapes — the result
    /// borrows the input bytes after one UTF-8 validation pass.
    fn string(&mut self) -> Result<Cow<'a, str>, JsonError> {
        self.expect_byte(b'"')?;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let s =
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| self.utf8_err(start, e))?;
                    self.pos += 1;
                    return Ok(Cow::Borrowed(s));
                }
                Some(b'\\') => break,
                Some(_) => self.pos += 1,
            }
        }
        self.string_owned(start)
    }

    /// Slow path: at least one escape — decode into an owned buffer,
    /// starting from the clean prefix scanned so far.
    fn string_owned(&mut self, start: usize) -> Result<Cow<'a, str>, JsonError> {
        let prefix = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| self.utf8_err(start, e))?;
        let mut s = String::with_capacity(prefix.len() + 16);
        s.push_str(prefix);
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(Cow::Owned(s));
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.unicode_escape()?;
                            s.push(cp);
                            continue; // unicode_escape advanced pos itself
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume the run of plain bytes up to the next
                    // quote or escape, validating UTF-8 once per run.
                    let run = self.pos;
                    while let Some(b) = self.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    let chunk =
                        std::str::from_utf8(&self.bytes[run..self.pos])
                            .map_err(|e| self.utf8_err(run, e))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        // at '\\u'; pos points at 'u'
        self.pos += 1;
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let hex_str =
            std::str::from_utf8(hex).map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex_str, 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        // Surrogate pair handling.
        if (0xd800..0xdc00).contains(&cp) {
            if self.bytes.get(self.pos) == Some(&b'\\')
                && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 2;
                let hex2 = self
                    .bytes
                    .get(self.pos..self.pos + 4)
                    .ok_or_else(|| self.err("truncated low surrogate"))?;
                let lo = u32::from_str_radix(
                    std::str::from_utf8(hex2).map_err(|_| self.err("bad"))?,
                    16,
                )
                .map_err(|_| self.err("bad low surrogate"))?;
                self.pos += 4;
                let c = 0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                return char::from_u32(c).ok_or_else(|| self.err("bad pair"));
            }
            return Ok('\u{fffd}');
        }
        Ok(char::from_u32(cp).unwrap_or('\u{fffd}'))
    }

    fn number(&mut self) -> Result<f64, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // The scanned range is ASCII by construction.
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>().map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(text: &str) -> Vec<Event<'_>> {
        let mut r = JsonReader::new(text.as_bytes());
        let mut out = Vec::new();
        loop {
            match r.next() {
                Ok(ev) => out.push(ev),
                Err(_) => break,
            }
            if r.depth() == 0 {
                break;
            }
        }
        r.finish().unwrap();
        out
    }

    #[test]
    fn scalar_events() {
        assert_eq!(events("null"), [Event::Null]);
        assert_eq!(events("true"), [Event::Bool(true)]);
        assert_eq!(events(" -2.5 "), [Event::Num(-2.5)]);
        assert_eq!(
            events("\"hi\""),
            [Event::Str(Cow::Borrowed("hi"))]
        );
    }

    #[test]
    fn container_event_stream() {
        let evs = events(r#"{"a":[1,{}],"b":null}"#);
        assert_eq!(
            evs,
            [
                Event::ObjStart,
                Event::Key(Cow::Borrowed("a")),
                Event::ArrStart,
                Event::Num(1.0),
                Event::ObjStart,
                Event::ObjEnd,
                Event::ArrEnd,
                Event::Key(Cow::Borrowed("b")),
                Event::Null,
                Event::ObjEnd,
            ]
        );
    }

    #[test]
    fn plain_strings_borrow_escaped_strings_own() {
        let text = r#"["plain","esc\n"]"#;
        let mut r = JsonReader::new(text.as_bytes());
        assert_eq!(r.next().unwrap(), Event::ArrStart);
        match r.next().unwrap() {
            Event::Str(Cow::Borrowed(s)) => assert_eq!(s, "plain"),
            other => panic!("expected borrowed str, got {other:?}"),
        }
        match r.next().unwrap() {
            Event::Str(Cow::Owned(s)) => assert_eq!(s, "esc\n"),
            other => panic!("expected owned str, got {other:?}"),
        }
        assert_eq!(r.next().unwrap(), Event::ArrEnd);
        r.finish().unwrap();
    }

    #[test]
    fn skip_value_skips_whole_containers() {
        let text = r#"{"skip":{"deep":[1,[2,{"x":3}]]},"keep":7}"#;
        let mut r = JsonReader::new(text.as_bytes());
        assert_eq!(r.next().unwrap(), Event::ObjStart);
        assert_eq!(r.next().unwrap(), Event::Key(Cow::Borrowed("skip")));
        r.skip_value().unwrap();
        assert_eq!(r.next().unwrap(), Event::Key(Cow::Borrowed("keep")));
        assert_eq!(r.next().unwrap(), Event::Num(7.0));
        assert_eq!(r.next().unwrap(), Event::ObjEnd);
        r.finish().unwrap();
    }

    #[test]
    fn errors_carry_byte_offsets() {
        let mut r = JsonReader::new(b"[1, oops]");
        assert_eq!(r.next().unwrap(), Event::ArrStart);
        assert_eq!(r.next().unwrap(), Event::Num(1.0));
        let err = r.next().unwrap_err();
        assert_eq!(err.offset, 4);
    }

    #[test]
    fn invalid_utf8_in_string_is_an_error_not_a_panic() {
        let mut bytes = b"\"ab".to_vec();
        bytes.push(0xff);
        bytes.extend_from_slice(b"cd\"");
        let mut r = JsonReader::new(&bytes);
        let err = r.next().unwrap_err();
        assert!(err.message.contains("utf-8"), "{err}");
        assert_eq!(err.offset, 3, "offset points at the bad byte");
    }

    #[test]
    fn truncated_mid_escape_is_an_error() {
        for text in [r#""abc\"#, r#""abc\u00"#, r#"{"k":"v\"#] {
            let mut r = JsonReader::new(text.as_bytes());
            let mut last = Ok(());
            for _ in 0..8 {
                match r.next() {
                    Ok(_) => continue,
                    Err(e) => {
                        last = Err(e);
                        break;
                    }
                }
            }
            assert!(last.is_err(), "{text} must fail");
        }
    }

    #[test]
    fn trailing_data_rejected_by_finish() {
        let mut r = JsonReader::new(b"[1] junk");
        while r.depth() > 0 || r.offset() == 0 {
            r.next().unwrap();
        }
        assert!(r.finish().is_err());
    }

    #[test]
    fn next_after_done_is_an_error() {
        let mut r = JsonReader::new(b"7");
        assert_eq!(r.next().unwrap(), Event::Num(7.0));
        assert!(r.next().is_err());
    }
}
