//! Direct-to-buffer JSON serialization — the write half of the
//! streaming core.
//!
//! [`JsonWriter`] serializes straight into one growable `String`
//! without ever materializing a [`Json`] tree: callers stream
//! `begin_obj`/`key`/`num`/... calls and the writer handles commas,
//! pretty-printing indentation and string escaping.  The tree API's
//! `Json::to_string_compact`/`to_string_pretty` are implemented on top
//! of [`JsonWriter::value`], so the streaming and tree paths share one
//! formatter and can never drift apart byte-wise — the invariant the
//! report/store/cache goldens depend on.
//!
//! Formatting rules (identical to the historical tree writer):
//! * compact mode has no whitespace at all;
//! * pretty mode indents two spaces per depth, puts every container
//!   item on its own line, renders empty containers as `[]`/`{}`, and
//!   writes `"key": value` with a single space after the colon;
//! * numbers with no fractional part and magnitude `< 9.0e15` render
//!   as integers, everything else through the shortest-roundtrip f64
//!   `Display`; non-finite values degrade to `null`;
//! * strings escape `"` `\` and control characters only — multi-byte
//!   UTF-8 passes through verbatim.

use super::Json;

/// Append `n` in the crate's canonical JSON number format.
pub fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; TALP metrics never produce them, but be
        // defensive rather than emit invalid documents.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        let s = format!("{n}");
        out.push_str(&s);
    }
}

/// Append `s` as a quoted, escaped JSON string.
pub fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Frame {
    Arr,
    Obj,
}

/// Streaming JSON serializer over one owned output buffer.
///
/// Misuse (a `key` outside an object, unbalanced `end_*`, two keys in
/// a row) is a programming error: debug builds assert, release builds
/// emit whatever was asked for — exactly like writing to a raw buffer.
#[derive(Debug)]
pub struct JsonWriter {
    out: String,
    pretty: bool,
    /// One entry per open container: (kind, has_items).
    stack: Vec<(Frame, bool)>,
    /// A key was just written; the next value belongs to it.
    after_key: bool,
}

impl JsonWriter {
    pub fn compact() -> JsonWriter {
        JsonWriter::with_capacity(256, false)
    }

    pub fn pretty() -> JsonWriter {
        JsonWriter::with_capacity(1024, true)
    }

    /// Pre-sized writer: hot paths (shard appends, cache saves, the
    /// report document) know their approximate output size and avoid
    /// re-allocation churn by reserving it up front.
    pub fn with_capacity(capacity: usize, pretty: bool) -> JsonWriter {
        JsonWriter {
            out: String::with_capacity(capacity),
            pretty,
            stack: Vec::new(),
            after_key: false,
        }
    }

    fn newline_indent(&mut self, depth: usize) {
        if self.pretty {
            self.out.push('\n');
            for _ in 0..depth * 2 {
                self.out.push(' ');
            }
        }
    }

    /// Comma + newline/indent bookkeeping before a key, or before a
    /// value in array/top-level position.  A value right after a key
    /// follows the `": "` separator instead.
    fn before_item(&mut self) {
        if self.after_key {
            self.after_key = false;
            return;
        }
        if let Some((_, has_items)) = self.stack.last_mut() {
            let first = !*has_items;
            *has_items = true;
            if !first {
                self.out.push(',');
            }
            let depth = self.stack.len();
            self.newline_indent(depth);
        }
    }

    pub fn begin_obj(&mut self) {
        self.before_item();
        self.out.push('{');
        self.stack.push((Frame::Obj, false));
    }

    pub fn end_obj(&mut self) {
        debug_assert!(!self.after_key, "end_obj right after a key");
        let (frame, has_items) =
            self.stack.pop().expect("end_obj with no open container");
        debug_assert_eq!(frame, Frame::Obj, "end_obj closing an array");
        if has_items {
            let depth = self.stack.len();
            self.newline_indent(depth);
        }
        self.out.push('}');
    }

    pub fn begin_arr(&mut self) {
        self.before_item();
        self.out.push('[');
        self.stack.push((Frame::Arr, false));
    }

    pub fn end_arr(&mut self) {
        debug_assert!(!self.after_key, "end_arr right after a key");
        let (frame, has_items) =
            self.stack.pop().expect("end_arr with no open container");
        debug_assert_eq!(frame, Frame::Arr, "end_arr closing an object");
        if has_items {
            let depth = self.stack.len();
            self.newline_indent(depth);
        }
        self.out.push(']');
    }

    /// Write an object key; the next value call supplies its value.
    pub fn key(&mut self, key: &str) {
        debug_assert!(
            matches!(self.stack.last(), Some((Frame::Obj, _))),
            "key outside an object"
        );
        debug_assert!(!self.after_key, "two keys in a row");
        self.before_item();
        write_escaped(&mut self.out, key);
        self.out.push(':');
        if self.pretty {
            self.out.push(' ');
        }
        self.after_key = true;
    }

    pub fn null(&mut self) {
        self.before_item();
        self.out.push_str("null");
    }

    pub fn boolean(&mut self, b: bool) {
        self.before_item();
        self.out.push_str(if b { "true" } else { "false" });
    }

    pub fn num(&mut self, n: f64) {
        self.before_item();
        write_num(&mut self.out, n);
    }

    pub fn str_val(&mut self, s: &str) {
        self.before_item();
        write_escaped(&mut self.out, s);
    }

    /// Serialize a whole [`Json`] tree at the current position — how
    /// the tree API renders itself, and the escape hatch for small
    /// subdocuments (e.g. an embedded gate verdict) inside an otherwise
    /// streamed document.
    pub fn value(&mut self, v: &Json) {
        match v {
            Json::Null => self.null(),
            Json::Bool(b) => self.boolean(*b),
            Json::Num(n) => self.num(*n),
            Json::Str(s) => self.str_val(s),
            Json::Arr(items) => {
                self.begin_arr();
                for item in items {
                    self.value(item);
                }
                self.end_arr();
            }
            Json::Obj(pairs) => {
                self.begin_obj();
                for (k, v) in pairs {
                    self.key(k);
                    self.value(v);
                }
                self.end_obj();
            }
        }
    }

    /// Replay one reader [`Event`](super::Event) — the reader→writer
    /// pipe used by the round-trip property tests.
    pub fn event(&mut self, ev: &super::Event<'_>) {
        use super::Event;
        match ev {
            Event::Null => self.null(),
            Event::Bool(b) => self.boolean(*b),
            Event::Num(n) => self.num(*n),
            Event::Str(s) => self.str_val(s),
            Event::ArrStart => self.begin_arr(),
            Event::ArrEnd => self.end_arr(),
            Event::ObjStart => self.begin_obj(),
            Event::ObjEnd => self.end_obj(),
            Event::Key(k) => self.key(k),
        }
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }

    /// Append a raw newline (JSONL record separators, trailing file
    /// newlines).
    pub fn newline(&mut self) {
        self.out.push('\n');
    }

    /// Finish and take the buffer.
    pub fn into_string(self) -> String {
        debug_assert!(
            self.stack.is_empty(),
            "into_string with {} unclosed container(s)",
            self.stack.len()
        );
        self.out
    }

    /// The output written so far (for incremental consumers).
    pub fn as_str(&self) -> &str {
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_matches_tree_writer() {
        let j = Json::parse(r#"{"a":1,"b":[true,null,"x"],"c":{},"d":[]}"#)
            .unwrap();
        let mut w = JsonWriter::compact();
        w.value(&j);
        assert_eq!(w.into_string(), j.to_string_compact());
    }

    #[test]
    fn pretty_matches_tree_writer() {
        let j = Json::parse(
            r#"{"a":[1,2],"b":{"c":null,"d":{"e":[[],{}]}},"s":"q\"q"}"#,
        )
        .unwrap();
        let mut w = JsonWriter::pretty();
        w.value(&j);
        let mut out = w.into_string();
        out.push('\n');
        assert_eq!(out, j.to_string_pretty());
    }

    #[test]
    fn streamed_object_shape() {
        let mut w = JsonWriter::compact();
        w.begin_obj();
        w.key("n");
        w.num(2.0);
        w.key("arr");
        w.begin_arr();
        w.str_val("a");
        w.boolean(false);
        w.end_arr();
        w.key("empty");
        w.begin_obj();
        w.end_obj();
        w.end_obj();
        assert_eq!(w.into_string(), r#"{"n":2,"arr":["a",false],"empty":{}}"#);
    }

    #[test]
    fn pretty_empty_containers_stay_inline() {
        let mut w = JsonWriter::pretty();
        w.begin_obj();
        w.key("a");
        w.begin_arr();
        w.end_arr();
        w.end_obj();
        assert_eq!(w.into_string(), "{\n  \"a\": []\n}");
    }

    #[test]
    fn top_level_scalars() {
        for (v, want) in [
            (Json::Null, "null"),
            (Json::Bool(true), "true"),
            (Json::Num(0.25), "0.25"),
            (Json::Str("hi".into()), "\"hi\""),
        ] {
            let mut w = JsonWriter::compact();
            w.value(&v);
            assert_eq!(w.into_string(), want);
        }
    }
}
