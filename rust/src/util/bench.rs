//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated measurement with mean/stddev/min reporting
//! and a `Table` pretty-printer used by the per-paper-table bench
//! binaries (`cargo bench` runs them via `harness = false`).

use std::time::Instant;

use super::stats::{fmt_duration, Welford};

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub iters: u32,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} ± {:>9} (min {:>10}, n={})",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.stddev_s),
            fmt_duration(self.min_s),
            self.iters
        )
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then `iters` measured.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    let mut min_s = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        w.push(dt);
        min_s = min_s.min(dt);
    }
    Measurement {
        name: name.to_string(),
        mean_s: w.mean(),
        stddev_s: w.stddev(),
        min_s,
        iters: iters.max(1),
    }
}

/// Text table builder for bench outputs that mirror the paper's tables.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let m = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.mean_s);
        assert_eq!(m.iters, 5);
        assert!(m.report().contains("spin"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["metric", "2x56", "4x56"]);
        t.row_strs(&["Parallel efficiency", "0.90", "0.63"]);
        t.row_strs(&["IPC scalability", "1.00", "3.10"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("Parallel efficiency"));
        // Columns aligned: every data line has the same pipe positions.
        let lines: Vec<&str> =
            r.lines().filter(|l| l.contains('|')).collect();
        let pipes: Vec<usize> = lines[0]
            .char_indices()
            .filter(|(_, c)| *c == '|')
            .map(|(i, _)| i)
            .collect();
        for l in &lines {
            let p: Vec<usize> = l
                .char_indices()
                .filter(|(_, c)| *c == '|')
                .map(|(i, _)| i)
                .collect();
            assert_eq!(p, pipes);
        }
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}
