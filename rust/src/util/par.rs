//! Scoped-thread worker pool (rayon is unavailable offline).
//!
//! [`parallel_map`] is the report engine's concurrency substrate: a
//! work-stealing-free, atomic-cursor fan-out over a slice that returns
//! results **in input order**, so callers stay byte-deterministic
//! regardless of worker scheduling (`--jobs 1` and `--jobs N` must
//! produce identical reports).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Hard ceiling on explicit worker requests: the CLI rejects larger
/// values up front (`cli::args::Args::get_jobs`), and library callers
/// that bypass it are clamped here instead of spawning an absurd pool.
pub const MAX_JOBS: usize = 512;

/// Resolve a `--jobs` request: 0 means "auto" (available parallelism,
/// capped at 16 — report workloads are IO + small-buffer CPU and stop
/// scaling well past that).  Explicit values are clamped to
/// [`MAX_JOBS`].
pub fn effective_jobs(jobs: usize) -> usize {
    if jobs > 0 {
        jobs.min(MAX_JOBS)
    } else {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .min(16)
            .max(1)
    }
}

/// Apply `f` to every item on up to `jobs` worker threads (0 = auto),
/// returning outputs in input order.  Panics in `f` propagate.
pub fn parallel_map<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = effective_jobs(jobs).min(n);
    if workers <= 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .unwrap()
                .expect("parallel_map: worker skipped a slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order() {
        let items: Vec<u64> = (0..257).collect();
        for jobs in [0, 1, 3, 8] {
            let out = parallel_map(&items, jobs, |&x| x * 2);
            assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(parallel_map(&none, 4, |x| *x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |x| x + 1), vec![8]);
    }

    #[test]
    fn effective_jobs_resolution() {
        assert_eq!(effective_jobs(3), 3);
        assert!(effective_jobs(0) >= 1);
        assert!(effective_jobs(0) <= 16);
        // Absurd explicit requests clamp instead of spawning a
        // machine-melting pool.
        assert_eq!(effective_jobs(usize::MAX), MAX_JOBS);
        assert_eq!(effective_jobs(MAX_JOBS), MAX_JOBS);
    }

    #[test]
    fn jobs_equal_results() {
        // The determinism contract the report engine relies on.
        let items: Vec<String> =
            (0..64).map(|i| format!("item-{i}")).collect();
        let a = parallel_map(&items, 1, |s| format!("<{s}>"));
        let b = parallel_map(&items, 4, |s| format!("<{s}>"));
        assert_eq!(a, b);
    }
}
