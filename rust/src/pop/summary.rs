//! Precomputed per-run POP metrics — the report engine's working set.
//!
//! [`RunMetrics`] is everything report rendering needs from one TALP
//! JSON (badges, scaling tables, time series, findings, Extra-P fits)
//! with the per-process arrays already reduced to [`RegionMetrics`].
//! Two jobs:
//!
//! 1. **Compute once**: the legacy path recomputed `pop::compute` for
//!    the same region in every consumer (badge + table + each time
//!    point); `RunMetrics::from_run` runs the reduction exactly once.
//! 2. **Cache on disk**: the JSON form (`to_json`/`from_json`) is what
//!    `pages::cache` persists between CI pipelines, so unchanged
//!    artifacts skip parse + reduce entirely on warm runs.
//!
//! Serialization must be a *fixpoint*: a `RunMetrics` read back from
//! the cache renders byte-identical pages.  f64 values go through the
//! shortest-roundtrip `Display` of `util::json`, integers stay below
//! 2^53, and timestamps are stored as raw unix seconds.

use anyhow::{bail, Context, Result};

use crate::sim::ResourceConfig;
use crate::talp::{GitMeta, RunData};
use crate::util::json::{Event, FieldCursor, Json, JsonReader, JsonWriter};

use super::metrics::{self, RegionMetrics};

/// One region's precomputed factors.
#[derive(Debug, Clone)]
pub struct RegionSummary {
    pub name: String,
    pub visits: u64,
    pub metrics: RegionMetrics,
}

/// One run, reduced to what report rendering consumes.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    /// File name relative to the scan root (deterministic tie-break for
    /// equal-timestamp runs).
    pub source: String,
    pub app: String,
    pub machine: String,
    /// End-of-execution wall clock (unix seconds).
    pub timestamp: i64,
    pub ranks: u32,
    pub threads: u32,
    pub nodes: u32,
    pub git: Option<GitMeta>,
    pub regions: Vec<RegionSummary>,
}

impl RunMetrics {
    /// Reduce a parsed run: one `pop::compute` per region.
    pub fn from_run(data: &RunData, source: &str) -> RunMetrics {
        RunMetrics {
            source: source.to_string(),
            app: data.app.clone(),
            machine: data.machine.clone(),
            timestamp: data.timestamp,
            ranks: data.ranks,
            threads: data.threads,
            nodes: data.nodes,
            git: data.git.clone(),
            regions: data
                .regions
                .iter()
                .map(|reg| RegionSummary {
                    name: reg.name.clone(),
                    visits: reg.visits,
                    metrics: metrics::compute(reg, data.threads),
                })
                .collect(),
        }
    }

    pub fn resources(&self) -> ResourceConfig {
        ResourceConfig::new(self.ranks, self.threads)
    }

    pub fn region(&self, name: &str) -> Option<&RegionSummary> {
        self.regions.iter().find(|r| r.name == name)
    }

    /// Same plot-axis rule as `RunData::effective_timestamp`: git commit
    /// time when stamped, execution end time otherwise.
    pub fn effective_timestamp(&self) -> i64 {
        self.git
            .as_ref()
            .map(|g| g.commit_timestamp)
            .unwrap_or(self.timestamp)
    }

    // ---------- cache JSON ----------
    //
    // Two symmetric codecs, one schema: the tree pair
    // (`to_json`/`from_json`) and the streaming pair
    // (`write_to`/`from_events`) used by the store shards, the metrics
    // cache and `report.json` emission, where per-run tree building
    // would dominate the warm path.  The byte-identity tests below pin
    // them together.

    /// Serialize into `w` (the exact document `to_json` builds).
    pub fn write_to(&self, w: &mut JsonWriter) {
        w.begin_obj();
        w.key("source");
        w.str_val(&self.source);
        w.key("app");
        w.str_val(&self.app);
        w.key("machine");
        w.str_val(&self.machine);
        w.key("timestamp");
        w.num(self.timestamp as f64);
        w.key("ranks");
        w.num(self.ranks as f64);
        w.key("threads");
        w.num(self.threads as f64);
        w.key("nodes");
        w.num(self.nodes as f64);
        if let Some(g) = &self.git {
            w.key("git");
            w.begin_obj();
            w.key("commit");
            w.str_val(&g.commit);
            w.key("branch");
            w.str_val(&g.branch);
            w.key("commit_timestamp");
            w.num(g.commit_timestamp as f64);
            w.key("message");
            w.str_val(&g.message);
            w.end_obj();
        }
        w.key("regions");
        w.begin_arr();
        for r in &self.regions {
            let m = &r.metrics;
            w.begin_obj();
            w.key("name");
            w.str_val(&r.name);
            w.key("visits");
            w.num(r.visits as f64);
            w.key("ncpus");
            w.num(m.ncpus as f64);
            w.key("nranks");
            w.num(m.nranks as f64);
            w.key("nthreads");
            w.num(m.nthreads as f64);
            w.key("elapsed_s");
            w.num(m.elapsed_s);
            w.key("total_useful_s");
            w.num(m.total_useful_s);
            w.key("total_useful_instructions");
            w.num(m.total_useful_instructions as f64);
            w.key("total_useful_cycles");
            w.num(m.total_useful_cycles as f64);
            w.key("pe");
            w.num(m.parallel_efficiency);
            w.key("mpi_pe");
            w.num(m.mpi_parallel_efficiency);
            w.key("mpi_comm_eff");
            w.num(m.mpi_communication_efficiency);
            w.key("mpi_lb");
            w.num(m.mpi_load_balance);
            w.key("mpi_lb_in");
            w.num(m.mpi_load_balance_in);
            w.key("mpi_lb_inter");
            w.num(m.mpi_load_balance_inter);
            w.key("omp_pe");
            w.num(m.omp_parallel_efficiency);
            w.key("omp_lb");
            w.num(m.omp_load_balance);
            w.key("omp_sched_eff");
            w.num(m.omp_scheduling_efficiency);
            w.key("omp_serial_eff");
            w.num(m.omp_serialization_efficiency);
            w.key("useful_ipc");
            w.num(m.useful_ipc);
            w.key("frequency_ghz");
            w.num(m.frequency_ghz);
            w.key("insn_per_cpu");
            w.num(m.insn_per_cpu);
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
    }

    /// Decode one `RunMetrics` object from the event stream (the
    /// reader sits in value position, e.g. right after a `"run"` key).
    /// Exactly one value is consumed on success; schema strictness
    /// mirrors [`RunMetrics::from_json`] — a missing or mistyped
    /// required field is an error, so a corrupt store/cache entry is
    /// dropped rather than silently defaulted.
    pub fn from_events(r: &mut JsonReader<'_>) -> Result<RunMetrics> {
        match r.next()? {
            Event::ObjStart => {}
            _ => bail!("cache entry: not an object"),
        }
        let mut source: Option<String> = None;
        let mut app: Option<String> = None;
        let mut machine: Option<String> = None;
        let mut timestamp: Option<f64> = None;
        let mut ranks: Option<f64> = None;
        let mut threads: Option<f64> = None;
        let mut nodes: Option<f64> = None;
        let mut git: Option<GitMeta> = None;
        let mut saw_regions = false;
        let mut regions: Vec<RegionSummary> = Vec::new();
        loop {
            match r.next()? {
                Event::ObjEnd => break,
                Event::Key(k) => match k.as_ref() {
                    "source" => source = r.str_opt()?.map(|s| s.into_owned()),
                    "app" => app = r.str_opt()?.map(|s| s.into_owned()),
                    "machine" => {
                        machine = r.str_opt()?.map(|s| s.into_owned())
                    }
                    "timestamp" => timestamp = r.f64_opt()?,
                    "ranks" => ranks = r.f64_opt()?,
                    "threads" => threads = r.f64_opt()?,
                    "nodes" => nodes = r.f64_opt()?,
                    "git" => git = Some(decode_git(r)?),
                    "regions" => {
                        saw_regions = true;
                        match r.next()? {
                            Event::ArrStart => loop {
                                match r.next()? {
                                    Event::ArrEnd => break,
                                    Event::ObjStart => {
                                        regions.push(decode_region(r)?)
                                    }
                                    _ => bail!(
                                        "cache region: not an object"
                                    ),
                                }
                            },
                            _ => bail!("cache entry: regions is not a list"),
                        }
                    }
                    _ => r.skip_value()?,
                },
                _ => unreachable!("object events"),
            }
        }
        if !saw_regions {
            bail!("cache entry: missing regions");
        }
        if regions.is_empty() {
            bail!("cache entry: no regions");
        }
        let num = |v: Option<f64>, key: &str| -> Result<f64> {
            v.with_context(|| format!("cache entry: missing {key}"))
        };
        Ok(RunMetrics {
            source: source.context("cache entry: missing source")?,
            app: app.unwrap_or_else(|| "unknown".to_string()),
            machine: machine.unwrap_or_else(|| "unknown".to_string()),
            timestamp: num(timestamp, "timestamp")? as i64,
            ranks: num(ranks, "ranks")? as u32,
            threads: num(threads, "threads")? as u32,
            nodes: num(nodes, "nodes")? as u32,
            git,
            regions,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.push_field("source", Json::Str(self.source.clone()));
        root.push_field("app", Json::Str(self.app.clone()));
        root.push_field("machine", Json::Str(self.machine.clone()));
        root.push_field("timestamp", Json::Num(self.timestamp as f64));
        root.push_field("ranks", Json::Num(self.ranks as f64));
        root.push_field("threads", Json::Num(self.threads as f64));
        root.push_field("nodes", Json::Num(self.nodes as f64));
        if let Some(g) = &self.git {
            root.push_field(
                "git",
                Json::from_pairs(vec![
                    ("commit", Json::Str(g.commit.clone())),
                    ("branch", Json::Str(g.branch.clone())),
                    (
                        "commit_timestamp",
                        Json::Num(g.commit_timestamp as f64),
                    ),
                    ("message", Json::Str(g.message.clone())),
                ]),
            );
        }
        let regions: Vec<Json> = self
            .regions
            .iter()
            .map(|r| {
                let m = &r.metrics;
                Json::from_pairs(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("visits", Json::Num(r.visits as f64)),
                    ("ncpus", Json::Num(m.ncpus as f64)),
                    ("nranks", Json::Num(m.nranks as f64)),
                    ("nthreads", Json::Num(m.nthreads as f64)),
                    ("elapsed_s", Json::Num(m.elapsed_s)),
                    ("total_useful_s", Json::Num(m.total_useful_s)),
                    (
                        "total_useful_instructions",
                        Json::Num(m.total_useful_instructions as f64),
                    ),
                    (
                        "total_useful_cycles",
                        Json::Num(m.total_useful_cycles as f64),
                    ),
                    ("pe", Json::Num(m.parallel_efficiency)),
                    ("mpi_pe", Json::Num(m.mpi_parallel_efficiency)),
                    (
                        "mpi_comm_eff",
                        Json::Num(m.mpi_communication_efficiency),
                    ),
                    ("mpi_lb", Json::Num(m.mpi_load_balance)),
                    ("mpi_lb_in", Json::Num(m.mpi_load_balance_in)),
                    ("mpi_lb_inter", Json::Num(m.mpi_load_balance_inter)),
                    ("omp_pe", Json::Num(m.omp_parallel_efficiency)),
                    ("omp_lb", Json::Num(m.omp_load_balance)),
                    (
                        "omp_sched_eff",
                        Json::Num(m.omp_scheduling_efficiency),
                    ),
                    (
                        "omp_serial_eff",
                        Json::Num(m.omp_serialization_efficiency),
                    ),
                    ("useful_ipc", Json::Num(m.useful_ipc)),
                    ("frequency_ghz", Json::Num(m.frequency_ghz)),
                    ("insn_per_cpu", Json::Num(m.insn_per_cpu)),
                ])
            })
            .collect();
        root.push_field("regions", Json::Arr(regions));
        root
    }

    pub fn from_json(j: &Json) -> Result<RunMetrics> {
        let num = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("cache entry: missing {key}"))
        };
        // Strict like every other field: a malformed git block must
        // drop the entry (forcing a safe re-parse), not default the
        // commit timestamp to 0 and silently reorder the history.
        let git = match j.get("git") {
            None => None,
            Some(g) => Some(GitMeta {
                commit: g.str_or("commit", "").to_string(),
                branch: g.str_or("branch", "").to_string(),
                commit_timestamp: g
                    .get("commit_timestamp")
                    .and_then(Json::as_f64)
                    .context("cache entry: git without commit_timestamp")?
                    as i64,
                message: g.str_or("message", "").to_string(),
            }),
        };
        let mut regions = Vec::new();
        for rj in j
            .get("regions")
            .and_then(Json::as_arr)
            .context("cache entry: missing regions")?
        {
            // Fields are read in serialization order, so the cursor
            // memo makes each of the ~22 lookups one comparison
            // instead of an O(fields) scan per field.
            let mut rc = FieldCursor::new(rj);
            let name = rc
                .get("name")
                .and_then(Json::as_str)
                .context("cache region: missing name")?
                .to_string();
            let mut rnum = |key: &str| -> Result<f64> {
                rc.get(key)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("cache region: missing {key}"))
            };
            regions.push(RegionSummary {
                name,
                visits: rnum("visits")? as u64,
                metrics: RegionMetrics {
                    ncpus: rnum("ncpus")? as u32,
                    nranks: rnum("nranks")? as u32,
                    nthreads: rnum("nthreads")? as u32,
                    elapsed_s: rnum("elapsed_s")?,
                    total_useful_s: rnum("total_useful_s")?,
                    total_useful_instructions: rnum(
                        "total_useful_instructions",
                    )? as u64,
                    total_useful_cycles: rnum("total_useful_cycles")? as u64,
                    parallel_efficiency: rnum("pe")?,
                    mpi_parallel_efficiency: rnum("mpi_pe")?,
                    mpi_communication_efficiency: rnum("mpi_comm_eff")?,
                    mpi_load_balance: rnum("mpi_lb")?,
                    mpi_load_balance_in: rnum("mpi_lb_in")?,
                    mpi_load_balance_inter: rnum("mpi_lb_inter")?,
                    omp_parallel_efficiency: rnum("omp_pe")?,
                    omp_load_balance: rnum("omp_lb")?,
                    omp_scheduling_efficiency: rnum("omp_sched_eff")?,
                    omp_serialization_efficiency: rnum("omp_serial_eff")?,
                    useful_ipc: rnum("useful_ipc")?,
                    frequency_ghz: rnum("frequency_ghz")?,
                    insn_per_cpu: rnum("insn_per_cpu")?,
                },
            });
        }
        if regions.is_empty() {
            bail!("cache entry: no regions");
        }
        Ok(RunMetrics {
            source: j
                .get("source")
                .and_then(Json::as_str)
                .context("cache entry: missing source")?
                .to_string(),
            app: j.str_or("app", "unknown").to_string(),
            machine: j.str_or("machine", "unknown").to_string(),
            timestamp: num("timestamp")? as i64,
            ranks: num("ranks")? as u32,
            threads: num("threads")? as u32,
            nodes: num("nodes")? as u32,
            git,
            regions,
        })
    }
}

/// Region field names in serialization order — the streaming decoder
/// guesses the next index first, so an in-order document never scans.
const REGION_NUM_KEYS: [&str; 21] = [
    "visits",
    "ncpus",
    "nranks",
    "nthreads",
    "elapsed_s",
    "total_useful_s",
    "total_useful_instructions",
    "total_useful_cycles",
    "pe",
    "mpi_pe",
    "mpi_comm_eff",
    "mpi_lb",
    "mpi_lb_in",
    "mpi_lb_inter",
    "omp_pe",
    "omp_lb",
    "omp_sched_eff",
    "omp_serial_eff",
    "useful_ipc",
    "frequency_ghz",
    "insn_per_cpu",
];

/// Decode the strict `git` block (the reader sits in value position).
/// A malformed block is an error, never a defaulted timestamp — it
/// would silently reorder histories (same rule as the tree decoder).
fn decode_git(r: &mut JsonReader<'_>) -> Result<GitMeta> {
    match r.next()? {
        Event::ObjStart => {}
        Event::ArrStart => {
            r.skip_value_rest()?;
            bail!("cache entry: git without commit_timestamp");
        }
        _ => bail!("cache entry: git without commit_timestamp"),
    }
    let mut commit = String::new();
    let mut branch = String::new();
    let mut ts: Option<f64> = None;
    let mut message = String::new();
    loop {
        match r.next()? {
            Event::ObjEnd => break,
            Event::Key(k) => match k.as_ref() {
                "commit" => {
                    commit =
                        r.str_opt()?.map(|s| s.into_owned()).unwrap_or_default()
                }
                "branch" => {
                    branch =
                        r.str_opt()?.map(|s| s.into_owned()).unwrap_or_default()
                }
                "commit_timestamp" => ts = r.f64_opt()?,
                "message" => {
                    message =
                        r.str_opt()?.map(|s| s.into_owned()).unwrap_or_default()
                }
                _ => r.skip_value()?,
            },
            _ => unreachable!("object events"),
        }
    }
    Ok(GitMeta {
        commit,
        branch,
        commit_timestamp: ts
            .context("cache entry: git without commit_timestamp")?
            as i64,
        message,
    })
}

/// Decode one region summary (the reader sits just past its `{`).
fn decode_region(r: &mut JsonReader<'_>) -> Result<RegionSummary> {
    let mut name: Option<String> = None;
    let mut vals: [Option<f64>; REGION_NUM_KEYS.len()] =
        [None; REGION_NUM_KEYS.len()];
    let mut next_idx = 0usize;
    loop {
        match r.next()? {
            Event::ObjEnd => break,
            Event::Key(k) => {
                let k = k.as_ref();
                if k == "name" {
                    name = r.str_opt()?.map(|s| s.into_owned());
                    continue;
                }
                // In-order documents hit the `next_idx` guess; a
                // reordered document falls back to a position scan.
                let idx = if REGION_NUM_KEYS.get(next_idx) == Some(&k) {
                    Some(next_idx)
                } else {
                    REGION_NUM_KEYS.iter().position(|kk| *kk == k)
                };
                match idx {
                    Some(i) => {
                        vals[i] = r.f64_opt()?;
                        next_idx = i + 1;
                    }
                    None => r.skip_value()?,
                }
            }
            _ => unreachable!("object events"),
        }
    }
    let get = |i: usize| -> Result<f64> {
        vals[i].with_context(|| {
            format!("cache region: missing {}", REGION_NUM_KEYS[i])
        })
    };
    Ok(RegionSummary {
        name: name.context("cache region: missing name")?,
        visits: get(0)? as u64,
        metrics: RegionMetrics {
            ncpus: get(1)? as u32,
            nranks: get(2)? as u32,
            nthreads: get(3)? as u32,
            elapsed_s: get(4)?,
            total_useful_s: get(5)?,
            total_useful_instructions: get(6)? as u64,
            total_useful_cycles: get(7)? as u64,
            parallel_efficiency: get(8)?,
            mpi_parallel_efficiency: get(9)?,
            mpi_communication_efficiency: get(10)?,
            mpi_load_balance: get(11)?,
            mpi_load_balance_in: get(12)?,
            mpi_load_balance_inter: get(13)?,
            omp_parallel_efficiency: get(14)?,
            omp_load_balance: get(15)?,
            omp_scheduling_efficiency: get(16)?,
            omp_serialization_efficiency: get(17)?,
            useful_ipc: get(18)?,
            frequency_ghz: get(19)?,
            insn_per_cpu: get(20)?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::{ProcStats, RegionData};
    use crate::util::json::canonicalize;

    fn sample_run() -> RunData {
        RunData {
            dlb_version: "t".into(),
            app: "app".into(),
            machine: "mn5".into(),
            timestamp: 1_700_000_123,
            ranks: 2,
            threads: 4,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 10.0,
                visits: 3,
                procs: (0..2)
                    .map(|r| ProcStats {
                        rank: r,
                        elapsed_s: 10.0,
                        useful_s: 30.0 + r as f64 * 0.777,
                        mpi_s: 1.0 / 3.0, // exercise non-terminating f64
                        useful_instructions: 1_000_000,
                        useful_cycles: 400_000,
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: Some(GitMeta {
                commit: "abcdef12".into(),
                branch: "main".into(),
                commit_timestamp: 1_699_999_999,
                message: "m".into(),
            }),
        }
    }

    #[test]
    fn from_run_reduces_each_region_once() {
        let rm = RunMetrics::from_run(&sample_run(), "exp/a.json");
        assert_eq!(rm.source, "exp/a.json");
        assert_eq!(rm.regions.len(), 1);
        let g = rm.region("Global").unwrap();
        assert_eq!(g.visits, 3);
        assert!(g.metrics.parallel_efficiency > 0.0);
        assert_eq!(rm.effective_timestamp(), 1_699_999_999);
        assert_eq!(rm.resources().label(), "2x4");
    }

    #[test]
    fn json_roundtrip_is_exact_fixpoint() {
        let rm = RunMetrics::from_run(&sample_run(), "exp/a.json");
        let j1 = rm.to_json();
        let back = RunMetrics::from_json(&j1).unwrap();
        // Bit-exact f64s: the cache must not perturb rendered pages.
        let (a, b) = (&rm.region("Global").unwrap().metrics,
                      &back.region("Global").unwrap().metrics);
        assert_eq!(a, b);
        assert_eq!(back.git.as_ref().unwrap().commit, "abcdef12");
        assert_eq!(back.timestamp, rm.timestamp);
        // And the serialized form itself is a fixpoint.
        let j2 = back.to_json();
        assert_eq!(canonicalize(&j1), canonicalize(&j2));
    }

    #[test]
    fn missing_fields_rejected() {
        for text in [
            "{}",
            r#"{"source":"x","timestamp":1,"ranks":2,"threads":1,
                "nodes":1,"regions":[]}"#,
            r#"{"source":"x","timestamp":1,"ranks":2,"threads":1,
                "nodes":1,"regions":[{"name":"g"}]}"#,
            // git block present but missing its commit_timestamp: must
            // be rejected, not defaulted (it would reorder histories).
            r#"{"source":"x","app":"a","machine":"m","timestamp":1,
                "ranks":1,"threads":1,"nodes":1,
                "git":{"commit":"abc","branch":"main"},
                "regions":[{"name":"g","visits":1,"ncpus":1,"nranks":1,
                "nthreads":1,"elapsed_s":1,"total_useful_s":1,
                "total_useful_instructions":1,"total_useful_cycles":1,
                "pe":1,"mpi_pe":1,"mpi_comm_eff":1,"mpi_lb":1,
                "mpi_lb_in":1,"mpi_lb_inter":1,"omp_pe":1,"omp_lb":1,
                "omp_sched_eff":1,"omp_serial_eff":1,"useful_ipc":1,
                "frequency_ghz":1,"insn_per_cpu":1}]}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(RunMetrics::from_json(&j).is_err(), "{text}");
        }
    }

    #[test]
    fn effective_timestamp_without_git() {
        let mut run = sample_run();
        run.git = None;
        let rm = RunMetrics::from_run(&run, "s");
        assert_eq!(rm.effective_timestamp(), 1_700_000_123);
    }

    // ---------- streaming codec vs tree codec ----------

    #[test]
    fn streaming_encoder_matches_tree() {
        for git in [true, false] {
            let mut run = sample_run();
            if !git {
                run.git = None;
            }
            let rm = RunMetrics::from_run(&run, "exp/a.json");
            let mut w = JsonWriter::compact();
            rm.write_to(&mut w);
            assert_eq!(w.into_string(), rm.to_json().to_string_compact());
            let mut w = JsonWriter::pretty();
            rm.write_to(&mut w);
            assert_eq!(
                w.into_string() + "\n",
                rm.to_json().to_string_pretty()
            );
        }
    }

    #[test]
    fn from_events_matches_from_json() {
        let rm = RunMetrics::from_run(&sample_run(), "exp/a.json");
        let text = rm.to_json().to_string_compact();
        let mut r = JsonReader::new(text.as_bytes());
        let back = RunMetrics::from_events(&mut r).unwrap();
        r.finish().unwrap();
        let tree = RunMetrics::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(
            back.to_json().to_string_compact(),
            tree.to_json().to_string_compact()
        );
        assert_eq!(back.git, tree.git);
    }

    #[test]
    fn from_events_rejects_what_from_json_rejects() {
        for text in [
            "{}",
            "[]",
            "7",
            r#"{"source":"x","timestamp":1,"ranks":2,"threads":1,
                "nodes":1,"regions":[]}"#,
            r#"{"source":"x","timestamp":1,"ranks":2,"threads":1,
                "nodes":1,"regions":[{"name":"g"}]}"#,
            r#"{"source":"x","app":"a","machine":"m","timestamp":1,
                "ranks":1,"threads":1,"nodes":1,
                "git":{"commit":"abc","branch":"main"},
                "regions":[{"name":"g","visits":1,"ncpus":1,"nranks":1,
                "nthreads":1,"elapsed_s":1,"total_useful_s":1,
                "total_useful_instructions":1,"total_useful_cycles":1,
                "pe":1,"mpi_pe":1,"mpi_comm_eff":1,"mpi_lb":1,
                "mpi_lb_in":1,"mpi_lb_inter":1,"omp_pe":1,"omp_lb":1,
                "omp_sched_eff":1,"omp_serial_eff":1,"useful_ipc":1,
                "frequency_ghz":1,"insn_per_cpu":1}]}"#,
        ] {
            let mut r = JsonReader::new(text.as_bytes());
            assert!(RunMetrics::from_events(&mut r).is_err(), "{text}");
            let j = Json::parse(text).unwrap();
            assert!(RunMetrics::from_json(&j).is_err(), "{text}");
        }
    }

    #[test]
    fn from_events_accepts_reordered_and_unknown_fields() {
        // The index-guess fast path must not make the decoder order-
        // sensitive: shuffle region fields, add unknown ones.
        let rm = RunMetrics::from_run(&sample_run(), "exp/a.json");
        let text = rm.to_json().to_string_compact();
        let j = Json::parse(&text).unwrap();
        // Reverse every region object's fields and bolt on an extra.
        let mut shuffled = j.clone();
        if let Some(Json::Arr(regions)) = shuffled.get_mut("regions") {
            for r in regions {
                if let Json::Obj(pairs) = r {
                    pairs.reverse();
                    pairs.push((
                        "future_field".to_string(),
                        Json::Arr(vec![Json::Num(1.0)]),
                    ));
                }
            }
        }
        let text = shuffled.to_string_compact();
        let mut r = JsonReader::new(text.as_bytes());
        let back = RunMetrics::from_events(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(
            back.region("Global").unwrap().metrics,
            rm.region("Global").unwrap().metrics
        );
    }
}
