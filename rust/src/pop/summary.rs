//! Precomputed per-run POP metrics — the report engine's working set.
//!
//! [`RunMetrics`] is everything report rendering needs from one TALP
//! JSON (badges, scaling tables, time series, findings, Extra-P fits)
//! with the per-process arrays already reduced to [`RegionMetrics`].
//! Two jobs:
//!
//! 1. **Compute once**: the legacy path recomputed `pop::compute` for
//!    the same region in every consumer (badge + table + each time
//!    point); `RunMetrics::from_run` runs the reduction exactly once.
//! 2. **Cache on disk**: the JSON form (`to_json`/`from_json`) is what
//!    `pages::cache` persists between CI pipelines, so unchanged
//!    artifacts skip parse + reduce entirely on warm runs.
//!
//! Serialization must be a *fixpoint*: a `RunMetrics` read back from
//! the cache renders byte-identical pages.  f64 values go through the
//! shortest-roundtrip `Display` of `util::json`, integers stay below
//! 2^53, and timestamps are stored as raw unix seconds.

use anyhow::{bail, Context, Result};

use crate::sim::ResourceConfig;
use crate::talp::{GitMeta, RunData};
use crate::util::json::Json;

use super::metrics::{self, RegionMetrics};

/// One region's precomputed factors.
#[derive(Debug, Clone)]
pub struct RegionSummary {
    pub name: String,
    pub visits: u64,
    pub metrics: RegionMetrics,
}

/// One run, reduced to what report rendering consumes.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    /// File name relative to the scan root (deterministic tie-break for
    /// equal-timestamp runs).
    pub source: String,
    pub app: String,
    pub machine: String,
    /// End-of-execution wall clock (unix seconds).
    pub timestamp: i64,
    pub ranks: u32,
    pub threads: u32,
    pub nodes: u32,
    pub git: Option<GitMeta>,
    pub regions: Vec<RegionSummary>,
}

impl RunMetrics {
    /// Reduce a parsed run: one `pop::compute` per region.
    pub fn from_run(data: &RunData, source: &str) -> RunMetrics {
        RunMetrics {
            source: source.to_string(),
            app: data.app.clone(),
            machine: data.machine.clone(),
            timestamp: data.timestamp,
            ranks: data.ranks,
            threads: data.threads,
            nodes: data.nodes,
            git: data.git.clone(),
            regions: data
                .regions
                .iter()
                .map(|reg| RegionSummary {
                    name: reg.name.clone(),
                    visits: reg.visits,
                    metrics: metrics::compute(reg, data.threads),
                })
                .collect(),
        }
    }

    pub fn resources(&self) -> ResourceConfig {
        ResourceConfig::new(self.ranks, self.threads)
    }

    pub fn region(&self, name: &str) -> Option<&RegionSummary> {
        self.regions.iter().find(|r| r.name == name)
    }

    /// Same plot-axis rule as `RunData::effective_timestamp`: git commit
    /// time when stamped, execution end time otherwise.
    pub fn effective_timestamp(&self) -> i64 {
        self.git
            .as_ref()
            .map(|g| g.commit_timestamp)
            .unwrap_or(self.timestamp)
    }

    // ---------- cache JSON ----------

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("source", Json::Str(self.source.clone()));
        root.set("app", Json::Str(self.app.clone()));
        root.set("machine", Json::Str(self.machine.clone()));
        root.set("timestamp", Json::Num(self.timestamp as f64));
        root.set("ranks", Json::Num(self.ranks as f64));
        root.set("threads", Json::Num(self.threads as f64));
        root.set("nodes", Json::Num(self.nodes as f64));
        if let Some(g) = &self.git {
            root.set(
                "git",
                Json::from_pairs(vec![
                    ("commit", Json::Str(g.commit.clone())),
                    ("branch", Json::Str(g.branch.clone())),
                    (
                        "commit_timestamp",
                        Json::Num(g.commit_timestamp as f64),
                    ),
                    ("message", Json::Str(g.message.clone())),
                ]),
            );
        }
        let regions: Vec<Json> = self
            .regions
            .iter()
            .map(|r| {
                let m = &r.metrics;
                Json::from_pairs(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("visits", Json::Num(r.visits as f64)),
                    ("ncpus", Json::Num(m.ncpus as f64)),
                    ("nranks", Json::Num(m.nranks as f64)),
                    ("nthreads", Json::Num(m.nthreads as f64)),
                    ("elapsed_s", Json::Num(m.elapsed_s)),
                    ("total_useful_s", Json::Num(m.total_useful_s)),
                    (
                        "total_useful_instructions",
                        Json::Num(m.total_useful_instructions as f64),
                    ),
                    (
                        "total_useful_cycles",
                        Json::Num(m.total_useful_cycles as f64),
                    ),
                    ("pe", Json::Num(m.parallel_efficiency)),
                    ("mpi_pe", Json::Num(m.mpi_parallel_efficiency)),
                    (
                        "mpi_comm_eff",
                        Json::Num(m.mpi_communication_efficiency),
                    ),
                    ("mpi_lb", Json::Num(m.mpi_load_balance)),
                    ("mpi_lb_in", Json::Num(m.mpi_load_balance_in)),
                    ("mpi_lb_inter", Json::Num(m.mpi_load_balance_inter)),
                    ("omp_pe", Json::Num(m.omp_parallel_efficiency)),
                    ("omp_lb", Json::Num(m.omp_load_balance)),
                    (
                        "omp_sched_eff",
                        Json::Num(m.omp_scheduling_efficiency),
                    ),
                    (
                        "omp_serial_eff",
                        Json::Num(m.omp_serialization_efficiency),
                    ),
                    ("useful_ipc", Json::Num(m.useful_ipc)),
                    ("frequency_ghz", Json::Num(m.frequency_ghz)),
                    ("insn_per_cpu", Json::Num(m.insn_per_cpu)),
                ])
            })
            .collect();
        root.set("regions", Json::Arr(regions));
        root
    }

    pub fn from_json(j: &Json) -> Result<RunMetrics> {
        let num = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("cache entry: missing {key}"))
        };
        // Strict like every other field: a malformed git block must
        // drop the entry (forcing a safe re-parse), not default the
        // commit timestamp to 0 and silently reorder the history.
        let git = match j.get("git") {
            None => None,
            Some(g) => Some(GitMeta {
                commit: g.str_or("commit", "").to_string(),
                branch: g.str_or("branch", "").to_string(),
                commit_timestamp: g
                    .get("commit_timestamp")
                    .and_then(Json::as_f64)
                    .context("cache entry: git without commit_timestamp")?
                    as i64,
                message: g.str_or("message", "").to_string(),
            }),
        };
        let mut regions = Vec::new();
        for rj in j
            .get("regions")
            .and_then(Json::as_arr)
            .context("cache entry: missing regions")?
        {
            let rnum = |key: &str| -> Result<f64> {
                rj.get(key)
                    .and_then(Json::as_f64)
                    .with_context(|| format!("cache region: missing {key}"))
            };
            regions.push(RegionSummary {
                name: rj
                    .get("name")
                    .and_then(Json::as_str)
                    .context("cache region: missing name")?
                    .to_string(),
                visits: rnum("visits")? as u64,
                metrics: RegionMetrics {
                    ncpus: rnum("ncpus")? as u32,
                    nranks: rnum("nranks")? as u32,
                    nthreads: rnum("nthreads")? as u32,
                    elapsed_s: rnum("elapsed_s")?,
                    total_useful_s: rnum("total_useful_s")?,
                    total_useful_instructions: rnum(
                        "total_useful_instructions",
                    )? as u64,
                    total_useful_cycles: rnum("total_useful_cycles")? as u64,
                    parallel_efficiency: rnum("pe")?,
                    mpi_parallel_efficiency: rnum("mpi_pe")?,
                    mpi_communication_efficiency: rnum("mpi_comm_eff")?,
                    mpi_load_balance: rnum("mpi_lb")?,
                    mpi_load_balance_in: rnum("mpi_lb_in")?,
                    mpi_load_balance_inter: rnum("mpi_lb_inter")?,
                    omp_parallel_efficiency: rnum("omp_pe")?,
                    omp_load_balance: rnum("omp_lb")?,
                    omp_scheduling_efficiency: rnum("omp_sched_eff")?,
                    omp_serialization_efficiency: rnum("omp_serial_eff")?,
                    useful_ipc: rnum("useful_ipc")?,
                    frequency_ghz: rnum("frequency_ghz")?,
                    insn_per_cpu: rnum("insn_per_cpu")?,
                },
            });
        }
        if regions.is_empty() {
            bail!("cache entry: no regions");
        }
        Ok(RunMetrics {
            source: j
                .get("source")
                .and_then(Json::as_str)
                .context("cache entry: missing source")?
                .to_string(),
            app: j.str_or("app", "unknown").to_string(),
            machine: j.str_or("machine", "unknown").to_string(),
            timestamp: num("timestamp")? as i64,
            ranks: num("ranks")? as u32,
            threads: num("threads")? as u32,
            nodes: num("nodes")? as u32,
            git,
            regions,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::{ProcStats, RegionData};
    use crate::util::json::canonicalize;

    fn sample_run() -> RunData {
        RunData {
            dlb_version: "t".into(),
            app: "app".into(),
            machine: "mn5".into(),
            timestamp: 1_700_000_123,
            ranks: 2,
            threads: 4,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: 10.0,
                visits: 3,
                procs: (0..2)
                    .map(|r| ProcStats {
                        rank: r,
                        elapsed_s: 10.0,
                        useful_s: 30.0 + r as f64 * 0.777,
                        mpi_s: 1.0 / 3.0, // exercise non-terminating f64
                        useful_instructions: 1_000_000,
                        useful_cycles: 400_000,
                        ..Default::default()
                    })
                    .collect(),
            }],
            git: Some(GitMeta {
                commit: "abcdef12".into(),
                branch: "main".into(),
                commit_timestamp: 1_699_999_999,
                message: "m".into(),
            }),
        }
    }

    #[test]
    fn from_run_reduces_each_region_once() {
        let rm = RunMetrics::from_run(&sample_run(), "exp/a.json");
        assert_eq!(rm.source, "exp/a.json");
        assert_eq!(rm.regions.len(), 1);
        let g = rm.region("Global").unwrap();
        assert_eq!(g.visits, 3);
        assert!(g.metrics.parallel_efficiency > 0.0);
        assert_eq!(rm.effective_timestamp(), 1_699_999_999);
        assert_eq!(rm.resources().label(), "2x4");
    }

    #[test]
    fn json_roundtrip_is_exact_fixpoint() {
        let rm = RunMetrics::from_run(&sample_run(), "exp/a.json");
        let j1 = rm.to_json();
        let back = RunMetrics::from_json(&j1).unwrap();
        // Bit-exact f64s: the cache must not perturb rendered pages.
        let (a, b) = (&rm.region("Global").unwrap().metrics,
                      &back.region("Global").unwrap().metrics);
        assert_eq!(a, b);
        assert_eq!(back.git.as_ref().unwrap().commit, "abcdef12");
        assert_eq!(back.timestamp, rm.timestamp);
        // And the serialized form itself is a fixpoint.
        let j2 = back.to_json();
        assert_eq!(canonicalize(&j1), canonicalize(&j2));
    }

    #[test]
    fn missing_fields_rejected() {
        for text in [
            "{}",
            r#"{"source":"x","timestamp":1,"ranks":2,"threads":1,
                "nodes":1,"regions":[]}"#,
            r#"{"source":"x","timestamp":1,"ranks":2,"threads":1,
                "nodes":1,"regions":[{"name":"g"}]}"#,
            // git block present but missing its commit_timestamp: must
            // be rejected, not defaulted (it would reorder histories).
            r#"{"source":"x","app":"a","machine":"m","timestamp":1,
                "ranks":1,"threads":1,"nodes":1,
                "git":{"commit":"abc","branch":"main"},
                "regions":[{"name":"g","visits":1,"ncpus":1,"nranks":1,
                "nthreads":1,"elapsed_s":1,"total_useful_s":1,
                "total_useful_instructions":1,"total_useful_cycles":1,
                "pe":1,"mpi_pe":1,"mpi_comm_eff":1,"mpi_lb":1,
                "mpi_lb_in":1,"mpi_lb_inter":1,"omp_pe":1,"omp_lb":1,
                "omp_sched_eff":1,"omp_serial_eff":1,"useful_ipc":1,
                "frequency_ghz":1,"insn_per_cpu":1}]}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(RunMetrics::from_json(&j).is_err(), "{text}");
        }
    }

    #[test]
    fn effective_timestamp_without_git() {
        let mut run = sample_run();
        run.git = None;
        let rm = RunMetrics::from_run(&run, "s");
        assert_eq!(rm.effective_timestamp(), 1_700_000_123);
    }
}
