//! POP fundamental performance factors [Wagner et al. 2018]: the metric
//! hierarchy, weak/strong scaling detection and the scaling-efficiency
//! table (the paper's central visualization).

pub mod extrap;
pub mod metrics;
pub mod scaling;
pub mod summary;
pub mod table;

pub use metrics::{compute, RegionMetrics};
pub use scaling::{detect_mode, reference_index, scalability, Scalability, ScalingMode};
pub use summary::{RegionSummary, RunMetrics};
pub use table::{build, build_from_metrics, Row, ScalingTable};
