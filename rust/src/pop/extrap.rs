//! Extra-P-style performance-model fitting (the paper's future work:
//! "exporting an Extra-P experiment from a collection of jsons ... to
//! extend the performance modeling capabilities" [Calotoiu et al.]).
//!
//! Fits the single-term PMNF hypothesis  `f(p) = a + b * p^c`  to a
//! metric measured at several resource configurations, by scanning a
//! small grid of exponents `c` (Extra-P does the same over its PMNF
//! search space) and solving the linear least squares for (a, b) at
//! each candidate.  The winner minimizes SMAPE; `c = 0` degenerates to
//! a constant model.

/// One fitted model.
#[derive(Debug, Clone)]
pub struct Model {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Symmetric mean absolute percentage error of the fit (0..1).
    pub smape: f64,
}

impl Model {
    pub fn predict(&self, p: f64) -> f64 {
        self.a + self.b * p.powf(self.c)
    }

    /// Human-readable form: "12.4 + 31.2 * p^-0.92".
    pub fn formula(&self) -> String {
        if self.b.abs() < 1e-12 || self.c == 0.0 {
            format!("{:.4}", self.a + self.b)
        } else {
            format!("{:.4} + {:.4} * p^{:.2}", self.a, self.b, self.c)
        }
    }

    /// Does the model predict the metric grows with resources (a
    /// scalability bug smell for time-like metrics)?
    pub fn grows(&self) -> bool {
        self.b > 1e-12 && self.c > 0.05
    }
}

/// Exponent candidates (Extra-P's default PMNF uses i/4 for i in
/// -12..=12 plus log terms; we keep the polynomial part).
fn exponent_grid() -> Vec<f64> {
    let mut v: Vec<f64> = (-12..=12).map(|i| i as f64 / 4.0).collect();
    v.retain(|c| c.abs() > 1e-9);
    v.push(0.0);
    v
}

/// Fit `f(p) = a + b*p^c` to (p, value) observations.  Needs >= 2
/// distinct p; returns None otherwise.
pub fn fit(points: &[(f64, f64)]) -> Option<Model> {
    let mut ps: Vec<f64> = points.iter().map(|(p, _)| *p).collect();
    ps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ps.dedup();
    if ps.len() < 2 {
        return None;
    }
    let mut best: Option<Model> = None;
    for c in exponent_grid() {
        let Some((a, b)) = lls(points, c) else {
            continue;
        };
        let model = Model { a, b, c, smape: 0.0 };
        let smape = smape(&model, points);
        let model = Model { smape, ..model };
        let better = match &best {
            None => true,
            // Prefer lower error; tie-break on simpler exponent.
            Some(m) => {
                smape < m.smape - 1e-9
                    || (smape < m.smape + 1e-9 && c.abs() < m.c.abs())
            }
        };
        if better {
            best = Some(model);
        }
    }
    best
}

/// Linear least squares for f(p) = a + b*x with x = p^c.
fn lls(points: &[(f64, f64)], c: f64) -> Option<(f64, f64)> {
    let n = points.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for (p, y) in points {
        let x = p.powf(c);
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    let det = n * sxx - sx * sx;
    if det.abs() < 1e-12 {
        return None;
    }
    let b = (n * sxy - sx * sy) / det;
    let a = (sy - b * sx) / n;
    Some((a, b))
}

fn smape(m: &Model, points: &[(f64, f64)]) -> f64 {
    let mut s = 0.0;
    for (p, y) in points {
        let f = m.predict(*p);
        let denom = (f.abs() + y.abs()).max(1e-12);
        s += (f - y).abs() / denom * 2.0;
    }
    s / points.len() as f64
}

/// Fit elapsed-time models per region from a set of runs of one
/// experiment (p = total cpus).  Returns (region, model) pairs.
pub fn fit_experiment(
    runs: &[&crate::talp::RunData],
    region_filter: &[String],
) -> Vec<(String, Model)> {
    let obs: Vec<(f64, String, f64)> = runs
        .iter()
        .flat_map(|run| {
            let p = run.resources().total_cpus() as f64;
            run.regions
                .iter()
                .map(move |reg| (p, reg.name.clone(), reg.elapsed_s))
        })
        .collect();
    fit_observations(obs, region_filter)
}

/// Same fit from precomputed metrics (the incremental report engine's
/// path — see `pop::summary`).
pub fn fit_experiment_metrics(
    runs: &[&crate::pop::RunMetrics],
    region_filter: &[String],
) -> Vec<(String, Model)> {
    let obs: Vec<(f64, String, f64)> = runs
        .iter()
        .flat_map(|run| {
            let p = run.resources().total_cpus() as f64;
            run.regions
                .iter()
                .map(move |reg| (p, reg.name.clone(), reg.metrics.elapsed_s))
        })
        .collect();
    fit_observations(obs, region_filter)
}

fn fit_observations(
    observations: Vec<(f64, String, f64)>,
    region_filter: &[String],
) -> Vec<(String, Model)> {
    use std::collections::BTreeMap;
    let mut by_region: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    for (p, name, elapsed) in observations {
        if !region_filter.is_empty() && !region_filter.contains(&name) {
            continue;
        }
        by_region.entry(name).or_default().push((p, elapsed));
    }
    by_region
        .into_iter()
        .filter_map(|(name, pts)| fit(&pts).map(|m| (name, m)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_perfect_strong_scaling() {
        // t = 0.5 + 100/p
        let pts: Vec<(f64, f64)> = [2.0, 4.0, 8.0, 16.0, 32.0]
            .iter()
            .map(|&p| (p, 0.5 + 100.0 / p))
            .collect();
        let m = fit(&pts).unwrap();
        assert!(m.smape < 1e-6, "{}", m.smape);
        assert!((m.c - (-1.0)).abs() < 1e-9, "c = {}", m.c);
        assert!((m.a - 0.5).abs() < 1e-6);
        assert!((m.b - 100.0).abs() < 1e-4);
        assert!(!m.grows());
        assert!(m.formula().contains("p^-1.00"));
    }

    #[test]
    fn recovers_constant_weak_scaling() {
        let pts = vec![(112.0, 10.01), (448.0, 9.99), (896.0, 10.0)];
        let m = fit(&pts).unwrap();
        assert!(m.smape < 0.01);
        assert!((m.predict(1792.0) - 10.0).abs() < 0.3);
        assert!(!m.grows());
    }

    #[test]
    fn detects_scalability_bug_growth() {
        // t = 1 + 0.01 * p^1.5 — the Extra-P "scalability bug" shape.
        let pts: Vec<(f64, f64)> = [4.0f64, 16.0, 64.0, 256.0]
            .iter()
            .map(|&p| (p, 1.0 + 0.01 * p.powf(1.5)))
            .collect();
        let m = fit(&pts).unwrap();
        assert!(m.grows(), "{:?}", m);
        assert!((m.c - 1.5).abs() < 0.26, "c = {}", m.c);
    }

    #[test]
    fn too_few_points_is_none() {
        assert!(fit(&[(2.0, 1.0)]).is_none());
        assert!(fit(&[(2.0, 1.0), (2.0, 1.1)]).is_none());
        assert!(fit(&[]).is_none());
    }

    #[test]
    fn noisy_fit_stays_reasonable() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(5);
        let pts: Vec<(f64, f64)> = [2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
            .iter()
            .map(|&p| {
                let noise = 1.0 + 0.02 * (rng.f64() - 0.5);
                (p, (2.0 + 50.0 / p) * noise)
            })
            .collect();
        let m = fit(&pts).unwrap();
        assert!(m.smape < 0.05, "{}", m.smape);
        assert!(m.c < -0.5, "c = {}", m.c);
    }

    #[test]
    fn fit_experiment_per_region() {
        use crate::talp::{ProcStats, RegionData, RunData};
        let run = |cpus: u32, e_global: f64, e_init: f64| RunData {
            dlb_version: "t".into(),
            app: "t".into(),
            machine: "mn5".into(),
            timestamp: 0,
            ranks: cpus,
            threads: 1,
            nodes: 1,
            regions: vec![
                RegionData {
                    name: "Global".into(),
                    elapsed_s: e_global,
                    visits: 1,
                    procs: (0..cpus)
                        .map(|r| ProcStats {
                            rank: r,
                            elapsed_s: e_global,
                            ..Default::default()
                        })
                        .collect(),
                },
                RegionData {
                    name: "initialize".into(),
                    elapsed_s: e_init,
                    visits: 1,
                    procs: (0..cpus)
                        .map(|r| ProcStats {
                            rank: r,
                            elapsed_s: e_init,
                            ..Default::default()
                        })
                        .collect(),
                },
            ],
            git: None,
        };
        let runs = vec![
            run(4, 25.0, 1.0 + 0.01 * 4.0),
            run(16, 6.5, 1.0 + 0.01 * 16.0),
            run(64, 1.8, 1.0 + 0.01 * 64.0),
        ];
        let refs: Vec<&RunData> = runs.iter().collect();
        let models = fit_experiment(&refs, &[]);
        assert_eq!(models.len(), 2);
        let global = &models.iter().find(|(n, _)| n == "Global").unwrap().1;
        assert!(global.c < -0.5, "Global should scale down: {global:?}");
        let init =
            &models.iter().find(|(n, _)| n == "initialize").unwrap().1;
        assert!(init.grows(), "initialize grows with p: {init:?}");
    }
}
