//! The scaling-efficiency table (paper Fig. 3, Tables 6 & 7).
//!
//! One table per experiment folder: columns are resource configurations
//! (ordered by resources, reference first), rows are the POP factor
//! hierarchy plus the absolute IPC / frequency / elapsed-time footer.
//! Hybrid runs get the full MPI+OpenMP hierarchy; MPI-only runs (threads
//! == 1) get the compact Fig. 3 layout.

use crate::sim::ResourceConfig;
use crate::talp::RunData;

use super::metrics::{self, RegionMetrics};
use super::scaling::{self, ScalingMode};

/// One rendered cell: a value or "-" (e.g. CPT's missing counters).
pub type Cell = Option<f64>;

/// Indentation level for a row (the hierarchy in the paper's tables).
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub depth: usize,
    pub cells: Vec<Cell>,
    /// Footer rows (IPC, GHz, seconds) are not efficiencies.
    pub is_footer: bool,
}

/// The scaling-efficiency table for one region.
#[derive(Debug, Clone)]
pub struct ScalingTable {
    pub region: String,
    pub mode: ScalingMode,
    pub columns: Vec<String>,
    pub rows: Vec<Row>,
}

/// Build the table for `region` from one run per configuration.
/// Runs are reordered by resources; the least-resource run is the
/// reference.  Returns None when the region is absent everywhere.
pub fn build(region: &str, runs: &[&RunData]) -> Option<ScalingTable> {
    let items: Vec<(ResourceConfig, RegionMetrics)> = runs
        .iter()
        .filter_map(|r| {
            r.region(region)
                .map(|reg| (r.resources(), metrics::compute(reg, r.threads)))
        })
        .collect();
    build_from_metrics(region, &items)
}

/// Build the table from precomputed per-config metrics (the incremental
/// report engine's path — `pages::cache` hands in [`RegionMetrics`]
/// without ever touching per-process data).  Semantics are identical to
/// [`build`].
pub fn build_from_metrics(
    region: &str,
    items: &[(ResourceConfig, RegionMetrics)],
) -> Option<ScalingTable> {
    if items.is_empty() {
        return None;
    }
    let mut items: Vec<(ResourceConfig, RegionMetrics)> = items.to_vec();
    items.sort_by_key(|(c, _)| {
        (c.total_cpus(), c.n_ranks, c.threads_per_rank)
    });
    let configs: Vec<ResourceConfig> =
        items.iter().map(|(c, _)| c.clone()).collect();
    let ms: Vec<RegionMetrics> = items.iter().map(|(_, m)| *m).collect();
    let reference = scaling::reference_index(&configs);
    let mode = scaling::detect_mode(&ms, reference);
    let scal: Vec<scaling::Scalability> = ms
        .iter()
        .map(|m| scaling::scalability(m, &ms[reference], mode))
        .collect();

    let hybrid = configs.iter().any(|c| c.threads_per_rank > 1);
    let n = items.len();
    let col = |f: &dyn Fn(usize) -> Cell| -> Vec<Cell> {
        (0..n).map(f).collect()
    };

    let mut rows: Vec<Row> = Vec::new();
    let mut push = |label: &str, depth: usize, cells: Vec<Cell>, footer: bool| {
        rows.push(Row {
            label: label.to_string(),
            depth,
            cells,
            is_footer: footer,
        });
    };

    push(
        "Global efficiency",
        0,
        col(&|i| Some(scal[i].global_efficiency)),
        false,
    );
    push(
        "Parallel efficiency",
        1,
        col(&|i| Some(ms[i].parallel_efficiency)),
        false,
    );
    if hybrid {
        push(
            "MPI Parallel efficiency",
            2,
            col(&|i| Some(ms[i].mpi_parallel_efficiency)),
            false,
        );
        push(
            "MPI Communication efficiency",
            3,
            col(&|i| Some(ms[i].mpi_communication_efficiency)),
            false,
        );
        push(
            "MPI Load balance",
            3,
            col(&|i| Some(ms[i].mpi_load_balance)),
            false,
        );
        push(
            "MPI In-node load balance",
            4,
            col(&|i| Some(ms[i].mpi_load_balance_in)),
            false,
        );
        push(
            "MPI Inter-node load balance",
            4,
            col(&|i| Some(ms[i].mpi_load_balance_inter)),
            false,
        );
        push(
            "OpenMP Parallel efficiency",
            2,
            col(&|i| Some(ms[i].omp_parallel_efficiency)),
            false,
        );
        push(
            "OpenMP Load balance",
            3,
            col(&|i| Some(ms[i].omp_load_balance)),
            false,
        );
        push(
            "OpenMP Scheduling efficiency",
            3,
            col(&|i| Some(ms[i].omp_scheduling_efficiency)),
            false,
        );
        push(
            "OpenMP Serialization efficiency",
            3,
            col(&|i| Some(ms[i].omp_serialization_efficiency)),
            false,
        );
    } else {
        // MPI-only compact layout (paper Fig. 3).
        push(
            "MPI Parallel efficiency",
            2,
            col(&|i| Some(ms[i].mpi_parallel_efficiency)),
            false,
        );
        push(
            "MPI Communication efficiency",
            3,
            col(&|i| Some(ms[i].mpi_communication_efficiency)),
            false,
        );
        push(
            "MPI Load balance",
            3,
            col(&|i| Some(ms[i].mpi_load_balance)),
            false,
        );
        push(
            "MPI In-node load balance",
            4,
            col(&|i| Some(ms[i].mpi_load_balance_in)),
            false,
        );
        push(
            "MPI Inter-node load balance",
            4,
            col(&|i| Some(ms[i].mpi_load_balance_inter)),
            false,
        );
    }
    push(
        "Computation scalability",
        1,
        col(&|i| Some(scal[i].computation_scalability)),
        false,
    );
    push(
        "Instructions scaling",
        2,
        col(&|i| Some(scal[i].instruction_scaling)),
        false,
    );
    push(
        "IPC scaling",
        2,
        col(&|i| Some(scal[i].ipc_scaling)),
        false,
    );
    push(
        "Frequency scaling",
        2,
        col(&|i| Some(scal[i].frequency_scaling)),
        false,
    );
    push("Useful IPC", 0, col(&|i| Some(ms[i].useful_ipc)), true);
    push(
        "Frequency [GHz]",
        0,
        col(&|i| Some(ms[i].frequency_ghz)),
        true,
    );
    push(
        "Elapsed time [s]",
        0,
        col(&|i| Some(ms[i].elapsed_s)),
        true,
    );

    Some(ScalingTable {
        region: region.to_string(),
        mode,
        columns: configs.iter().map(|c| c.label()).collect(),
        rows,
    })
}

impl ScalingTable {
    /// Insert a row right after the row labelled `after` (tool-specific
    /// extensions like the BSC/CPT transfer/serialization split).
    pub fn insert_after(&mut self, after: &str, row: Row) {
        let pos = self
            .rows
            .iter()
            .position(|r| r.label == after)
            .map(|i| i + 1)
            .unwrap_or(self.rows.len());
        self.rows.insert(pos, row);
    }

    /// Blank a row's cells (CPT's missing hardware counters).
    pub fn blank_row(&mut self, label: &str) {
        if let Some(r) = self.rows.iter_mut().find(|r| r.label == label) {
            for c in &mut r.cells {
                *c = None;
            }
        }
    }

    pub fn cell(&self, label: &str, column: usize) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.label == label)
            .and_then(|r| r.cells.get(column).copied().flatten())
    }

    /// Format a value the way the paper does (2 decimals, footer rows
    /// adaptive).
    pub fn fmt_cell(v: Cell, footer: bool) -> String {
        match v {
            None => "-".to_string(),
            Some(x) if footer && x >= 100.0 => format!("{x:.1}"),
            Some(x) => format!("{x:.2}"),
        }
    }

    /// Plain-text rendering (benches / CLI).
    pub fn render_text(&self) -> String {
        let mut t = crate::util::bench::Table::new(
            &format!(
                "Scaling-efficiency table — region '{}' ({} scaling)",
                self.region,
                self.mode.name()
            ),
            &std::iter::once("Metrics")
                .chain(self.columns.iter().map(|s| s.as_str()))
                .collect::<Vec<_>>(),
        );
        for row in &self.rows {
            let mut cells =
                vec![format!("{}{}", "  ".repeat(row.depth), row.label)];
            cells.extend(
                row.cells
                    .iter()
                    .map(|c| Self::fmt_cell(*c, row.is_footer)),
            );
            t.row(&cells);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::{ProcStats, RegionData};

    fn run(ranks: u32, threads: u32, useful_per_rank: f64, e: f64, insn: u64) -> RunData {
        let procs = (0..ranks)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: e,
                useful_s: useful_per_rank,
                mpi_s: 0.05 * e,
                mpi_worker_idle_s: 0.05 * e * (threads - 1) as f64,
                omp_serialization_s: 0.01 * e,
                omp_scheduling_s: 0.01 * e,
                omp_barrier_s: 0.02 * e,
                useful_instructions: insn / ranks as u64,
                useful_cycles: insn / ranks as u64 / 2,
            })
            .collect();
        RunData {
            dlb_version: "t".into(),
            app: "t".into(),
            machine: "mn5".into(),
            timestamp: 0,
            ranks,
            threads,
            nodes: 1,
            regions: vec![RegionData {
                name: "Global".into(),
                elapsed_s: e,
                visits: 1,
                procs,
            }],
            git: None,
        }
    }

    #[test]
    fn builds_hybrid_table_with_all_rows() {
        let a = run(2, 4, 7.0, 2.0, 1_000_000);
        let b = run(4, 4, 3.2, 1.1, 1_050_000);
        let t = build("Global", &[&a, &b]).unwrap();
        assert_eq!(t.columns, vec!["2x4", "4x4"]);
        assert_eq!(t.mode, ScalingMode::Strong);
        for label in [
            "Global efficiency",
            "Parallel efficiency",
            "MPI Parallel efficiency",
            "OpenMP Parallel efficiency",
            "OpenMP Serialization efficiency",
            "Computation scalability",
            "Instructions scaling",
            "IPC scaling",
            "Frequency scaling",
            "Useful IPC",
            "Frequency [GHz]",
            "Elapsed time [s]",
        ] {
            assert!(
                t.cell(label, 0).is_some(),
                "missing row {label}"
            );
        }
        // Reference column scales to 1.
        assert!((t.cell("Instructions scaling", 0).unwrap() - 1.0).abs() < 1e-9);
        assert!((t.cell("IPC scaling", 0).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mpi_only_table_drops_openmp_rows() {
        let a = run(112, 1, 1.8, 2.0, 1_000_000);
        let b = run(224, 1, 0.8, 1.0, 1_100_000);
        let t = build("Global", &[&a, &b]).unwrap();
        assert!(t.rows.iter().all(|r| !r.label.contains("OpenMP")));
        assert!(t.cell("MPI In-node load balance", 0).is_some());
    }

    #[test]
    fn columns_sorted_reference_first() {
        let a = run(8, 4, 1.0, 1.0, 1_000_000);
        let b = run(2, 4, 4.0, 4.0, 1_000_000);
        let t = build("Global", &[&a, &b]).unwrap();
        assert_eq!(t.columns, vec!["2x4", "8x4"]);
    }

    #[test]
    fn absent_region_returns_none() {
        let a = run(2, 4, 1.0, 1.0, 100);
        assert!(build("initialize", &[&a]).is_none());
    }

    #[test]
    fn render_text_contains_values() {
        let a = run(2, 4, 7.0, 2.0, 1_000_000);
        let txt = build("Global", &[&a]).unwrap().render_text();
        assert!(txt.contains("Global efficiency"));
        assert!(txt.contains("2x4"));
        assert!(txt.contains("Elapsed time [s]"));
    }

    #[test]
    fn fmt_cell_styles() {
        assert_eq!(ScalingTable::fmt_cell(None, false), "-");
        assert_eq!(ScalingTable::fmt_cell(Some(0.904), false), "0.90");
        assert_eq!(ScalingTable::fmt_cell(Some(531.38), true), "531.4");
    }
}
