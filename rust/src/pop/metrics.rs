//! POP fundamental performance factors, computed from TALP raw data.
//!
//! Definitions (hybrid MPI+OpenMP; all reduce to the classic MPI-only
//! model when threads == 1).  For a region with global elapsed `E`,
//! per-process elapsed `E_p`, per-process master MPI time `mpi_p`,
//! thread count `T`, and thread-summed useful time `u_p`:
//!
//! ```text
//! ncpu               = P * T
//! PE                 = Σ u_p / (ncpu * E)                (parallel efficiency)
//! outMPI_p           = E_p - mpi_p                       (process MPI timeline)
//! MPI CommE          = max_p outMPI_p / E
//! MPI LB             = mean_p outMPI_p / max_p outMPI_p
//! MPI PE             = MPI LB * MPI CommE = mean_p outMPI_p / E
//!   inter-node LB    = mean_nodes(max_{p∈node} outMPI) / max_p outMPI
//!   in-node LB       = MPI LB / inter-node LB
//! avail              = Σ_p T * outMPI_p                  (cpu time not lost to MPI)
//! OMP Serialization  = (avail - Σ serial_p) / avail
//! OMP Scheduling     = (avail - Σ serial - Σ sched) / (avail - Σ serial)
//! OMP LB             = Σ u / (avail - Σ serial - Σ sched)
//! OMP PE             = Serialization * Scheduling * LB  ( = PE / MPI PE )
//! ```
//!
//! The chain is multiplicative by construction; the per-cpu accounting
//! identity `T*E_p = u_p + T*mpi_p + serial_p + sched_p + barrier_p`
//! (sim::engine guarantees it up to instrumentation perturbation) makes
//! `OMP LB` equal `1 - barrier/(avail - serial - sched)`.
//!
//! Computation scalability (vs the least-resource reference config) is in
//! `pop::scaling`; `Global efficiency = PE * Computation scalability`.

use crate::talp::RegionData;

/// All absolute (per-config) factors for one region.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegionMetrics {
    pub ncpus: u32,
    pub nranks: u32,
    pub nthreads: u32,
    pub elapsed_s: f64,
    pub total_useful_s: f64,
    pub total_useful_instructions: u64,
    pub total_useful_cycles: u64,

    pub parallel_efficiency: f64,
    pub mpi_parallel_efficiency: f64,
    pub mpi_communication_efficiency: f64,
    pub mpi_load_balance: f64,
    pub mpi_load_balance_in: f64,
    pub mpi_load_balance_inter: f64,
    pub omp_parallel_efficiency: f64,
    pub omp_load_balance: f64,
    pub omp_scheduling_efficiency: f64,
    pub omp_serialization_efficiency: f64,

    /// Aggregate useful IPC and frequency (GHz).
    pub useful_ipc: f64,
    pub frequency_ghz: f64,
    /// Average useful instructions per cpu (scaling-mode detection).
    pub insn_per_cpu: f64,
}

fn clamp01(x: f64) -> f64 {
    if x.is_finite() {
        x.clamp(0.0, 1.0)
    } else {
        0.0
    }
}

fn ratio(num: f64, den: f64) -> f64 {
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Compute the factor hierarchy for one region of one run.
pub fn compute(region: &RegionData, nthreads: u32) -> RegionMetrics {
    let p = region.procs.len().max(1) as f64;
    let t = nthreads.max(1) as f64;
    let ncpu = p * t;
    let e = region.elapsed_s.max(0.0);

    let total_useful: f64 = region.procs.iter().map(|x| x.useful_s).sum();
    let total_insn: u64 =
        region.procs.iter().map(|x| x.useful_instructions).sum();
    let total_cycles: u64 =
        region.procs.iter().map(|x| x.useful_cycles).sum();

    let out_mpi: Vec<f64> = region
        .procs
        .iter()
        .map(|x| (x.elapsed_s - x.mpi_s).max(0.0))
        .collect();
    let max_out = out_mpi.iter().cloned().fold(0.0f64, f64::max);
    let mean_out = out_mpi.iter().sum::<f64>() / p;

    let pe = clamp01(ratio(total_useful, ncpu * e));
    let comm_e = clamp01(ratio(max_out, e));
    let lb = clamp01(ratio(mean_out, max_out));
    let mpi_pe = clamp01(lb * comm_e);

    // Node grouping for the in/inter split.  Node maxima are weighted by
    // node population so that `in * inter == LB` holds exactly even for
    // uneven rank placements:
    //   inter = Σ_n pop_n * max_n / (P * max_all),  in = mean_p / wmean.
    let mut node_stats: std::collections::BTreeMap<u32, (f64, u32)> =
        std::collections::BTreeMap::new();
    for (proc, &o) in region.procs.iter().zip(&out_mpi) {
        let ent = node_stats.entry(proc.node).or_insert((0.0, 0));
        ent.0 = ent.0.max(o);
        ent.1 += 1;
    }
    let weighted_node_max = node_stats
        .values()
        .map(|(mx, pop)| mx * *pop as f64)
        .sum::<f64>()
        / p;
    let lb_inter = clamp01(ratio(weighted_node_max, max_out));
    let lb_in = clamp01(ratio(mean_out, weighted_node_max));

    // OpenMP decomposition over the non-MPI cpu time.
    let avail: f64 = out_mpi.iter().map(|o| o * t).sum();
    let serial: f64 =
        region.procs.iter().map(|x| x.omp_serialization_s).sum();
    let sched: f64 = region.procs.iter().map(|x| x.omp_scheduling_s).sum();
    let omp_serial_eff = clamp01(ratio(avail - serial, avail));
    let omp_sched_eff =
        clamp01(ratio(avail - serial - sched, avail - serial));
    let omp_lb = clamp01(ratio(total_useful, avail - serial - sched));
    let omp_pe = clamp01(omp_serial_eff * omp_sched_eff * omp_lb);

    let ipc = ratio(total_insn as f64, total_cycles as f64);
    let freq = ratio(total_cycles as f64, total_useful * 1e9);

    RegionMetrics {
        ncpus: ncpu as u32,
        nranks: p as u32,
        nthreads,
        elapsed_s: e,
        total_useful_s: total_useful,
        total_useful_instructions: total_insn,
        total_useful_cycles: total_cycles,
        parallel_efficiency: pe,
        mpi_parallel_efficiency: mpi_pe,
        mpi_communication_efficiency: comm_e,
        mpi_load_balance: lb,
        mpi_load_balance_in: lb_in,
        mpi_load_balance_inter: lb_inter,
        omp_parallel_efficiency: omp_pe,
        omp_load_balance: omp_lb,
        omp_scheduling_efficiency: omp_sched_eff,
        omp_serialization_efficiency: omp_serial_eff,
        useful_ipc: ipc,
        frequency_ghz: freq,
        insn_per_cpu: total_insn as f64 / ncpu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::talp::ProcStats;

    /// Hand-built region: 2 ranks x 2 threads, E = 10 s.
    /// rank0: useful 36 (of 40 cpu-s), mpi 1;  rank1: useful 30, mpi 3.
    fn region() -> RegionData {
        let mk = |rank, node, useful, mpi, serial, sched, barrier| ProcStats {
            rank,
            node,
            elapsed_s: 10.0,
            useful_s: useful,
            mpi_s: mpi,
            mpi_worker_idle_s: mpi,
            omp_serialization_s: serial,
            omp_scheduling_s: sched,
            omp_barrier_s: barrier,
            useful_instructions: (useful * 1.0e9) as u64,
            useful_cycles: (useful * 0.5e9) as u64,
        };
        RegionData {
            name: "Global".into(),
            elapsed_s: 10.0,
            visits: 1,
            procs: vec![
                mk(0, 0, 17.0, 1.0, 0.4, 0.2, 0.4),
                mk(1, 1, 13.0, 3.0, 0.4, 0.2, 0.4),
            ],
        }
    }

    #[test]
    fn parallel_efficiency_definition() {
        let m = compute(&region(), 2);
        // PE = (17+13) / (4 cpus * 10 s) = 0.75
        assert!((m.parallel_efficiency - 0.75).abs() < 1e-9);
    }

    #[test]
    fn mpi_hierarchy_multiplies() {
        let m = compute(&region(), 2);
        // outMPI = [9, 7]; CommE = 0.9; LB = 8/9
        assert!((m.mpi_communication_efficiency - 0.9).abs() < 1e-9);
        assert!((m.mpi_load_balance - 8.0 / 9.0).abs() < 1e-9);
        assert!(
            (m.mpi_parallel_efficiency
                - m.mpi_communication_efficiency * m.mpi_load_balance)
                .abs()
                < 1e-9
        );
        // ranks on different nodes: inter-node LB carries everything.
        assert!((m.mpi_load_balance_inter - m.mpi_load_balance).abs() < 1e-9);
        assert!((m.mpi_load_balance_in - 1.0).abs() < 1e-9);
    }

    #[test]
    fn same_node_moves_imbalance_in_node() {
        let mut r = region();
        r.procs[1].node = 0;
        let m = compute(&r, 2);
        assert!((m.mpi_load_balance_inter - 1.0).abs() < 1e-9);
        assert!((m.mpi_load_balance_in - m.mpi_load_balance).abs() < 1e-9);
    }

    #[test]
    fn omp_chain_multiplies_to_pe_over_mpi_pe() {
        let m = compute(&region(), 2);
        let chain = m.omp_serialization_efficiency
            * m.omp_scheduling_efficiency
            * m.omp_load_balance;
        assert!((chain - m.omp_parallel_efficiency).abs() < 1e-9);
        let pe_split = m.mpi_parallel_efficiency * m.omp_parallel_efficiency;
        assert!(
            (pe_split - m.parallel_efficiency).abs() < 0.02,
            "hierarchy should compose: {pe_split} vs {}",
            m.parallel_efficiency
        );
    }

    #[test]
    fn ipc_and_frequency() {
        let m = compute(&region(), 2);
        assert!((m.useful_ipc - 2.0).abs() < 1e-9); // 1e9 insn / 0.5e9 cyc per s
        assert!((m.frequency_ghz - 0.5).abs() < 1e-9);
    }

    #[test]
    fn perfect_run_scores_one() {
        let procs: Vec<ProcStats> = (0..4)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: 10.0,
                useful_s: 20.0, // 2 threads * 10 s
                mpi_s: 0.0,
                ..Default::default()
            })
            .collect();
        let r = RegionData {
            name: "x".into(),
            elapsed_s: 10.0,
            visits: 1,
            procs,
        };
        let m = compute(&r, 2);
        assert!((m.parallel_efficiency - 1.0).abs() < 1e-9);
        assert!((m.mpi_parallel_efficiency - 1.0).abs() < 1e-9);
        assert!((m.omp_parallel_efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        let r = RegionData {
            name: "empty".into(),
            elapsed_s: 0.0,
            visits: 0,
            procs: vec![ProcStats::default()],
        };
        let m = compute(&r, 1);
        assert_eq!(m.parallel_efficiency, 0.0);
        assert_eq!(m.useful_ipc, 0.0);
    }

    #[test]
    fn efficiencies_bounded_property() {
        use crate::util::propcheck;
        propcheck::check("efficiencies in [0,1]", 256, |rng| {
            let p = 1 + rng.below(6) as usize;
            let t = 1 + rng.below(8) as u32;
            let e = rng.range_f64(0.1, 100.0);
            let procs: Vec<ProcStats> = (0..p)
                .map(|r| {
                    let mpi = rng.range_f64(0.0, e * 0.5);
                    let used = rng.range_f64(0.0, (e - mpi) * t as f64);
                    ProcStats {
                        rank: r as u32,
                        node: rng.below(3) as u32,
                        elapsed_s: e,
                        useful_s: used,
                        mpi_s: mpi,
                        mpi_worker_idle_s: mpi * (t - 1) as f64,
                        omp_serialization_s: rng.range_f64(0.0, e),
                        omp_scheduling_s: rng.range_f64(0.0, e),
                        omp_barrier_s: rng.range_f64(0.0, e),
                        useful_instructions: rng.below(1 << 40),
                        useful_cycles: rng.below(1 << 40) + 1,
                    }
                })
                .collect();
            let r = RegionData {
                name: "prop".into(),
                elapsed_s: e,
                visits: 1,
                procs,
            };
            let m = compute(&r, t);
            for (name, v) in [
                ("PE", m.parallel_efficiency),
                ("MPI PE", m.mpi_parallel_efficiency),
                ("CommE", m.mpi_communication_efficiency),
                ("LB", m.mpi_load_balance),
                ("LB in", m.mpi_load_balance_in),
                ("LB inter", m.mpi_load_balance_inter),
                ("OMP PE", m.omp_parallel_efficiency),
                ("OMP LB", m.omp_load_balance),
                ("OMP sched", m.omp_scheduling_efficiency),
                ("OMP serial", m.omp_serialization_efficiency),
            ] {
                if !(0.0..=1.0).contains(&v) {
                    return Err(format!("{name} = {v} out of [0,1]"));
                }
            }
            // Sub-factors must compose into their parents.
            let mpi = m.mpi_load_balance * m.mpi_communication_efficiency;
            if (mpi - m.mpi_parallel_efficiency).abs() > 1e-9 {
                return Err(format!(
                    "MPI PE {} != LB*CommE {}",
                    m.mpi_parallel_efficiency, mpi
                ));
            }
            let inout = m.mpi_load_balance_in * m.mpi_load_balance_inter;
            if (inout - m.mpi_load_balance).abs() > 1e-6 {
                return Err(format!(
                    "LB {} != in*inter {}",
                    m.mpi_load_balance, inout
                ));
            }
            Ok(())
        });
    }
}
