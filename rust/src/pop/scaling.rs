//! Scaling-mode detection and computation-scalability factors.
//!
//! Paper §Scaling-efficiency table: "for weak scaling the instructions
//! executed per CPU are constant.  If this condition is violated, we
//! detect strong scaling.  The scaling mode only influences the
//! computation of the instruction scaling."  The reference case is the
//! configuration with the least resources.

use crate::sim::ResourceConfig;

use super::metrics::RegionMetrics;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingMode {
    Weak,
    Strong,
    /// Single configuration — scalabilities are all 1 by definition.
    Comparison,
}

impl ScalingMode {
    pub fn name(&self) -> &'static str {
        match self {
            ScalingMode::Weak => "weak",
            ScalingMode::Strong => "strong",
            ScalingMode::Comparison => "comparison",
        }
    }
}

/// Relative (vs-reference) factors for one configuration.
#[derive(Debug, Clone, Copy)]
pub struct Scalability {
    pub instruction_scaling: f64,
    pub ipc_scaling: f64,
    pub frequency_scaling: f64,
    pub computation_scalability: f64,
    pub global_efficiency: f64,
}

/// Tolerance on instructions-per-cpu constancy for weak-scaling
/// detection (fractional deviation from the reference).
pub const WEAK_TOLERANCE: f64 = 0.2;

/// Pick the reference configuration: least total cpus, then least ranks
/// (the paper: "the resource configuration with the least resources").
pub fn reference_index(configs: &[ResourceConfig]) -> usize {
    configs
        .iter()
        .enumerate()
        .min_by_key(|(_, c)| (c.total_cpus(), c.n_ranks))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Detect weak vs strong scaling from instructions-per-cpu constancy.
pub fn detect_mode(metrics: &[RegionMetrics], reference: usize) -> ScalingMode {
    if metrics.len() < 2 {
        return ScalingMode::Comparison;
    }
    let r = metrics[reference].insn_per_cpu;
    if r <= 0.0 {
        return ScalingMode::Strong;
    }
    // All configurations at the same cpu count is a comparison, not a
    // scaling experiment.
    if metrics.iter().all(|m| m.ncpus == metrics[reference].ncpus) {
        return ScalingMode::Comparison;
    }
    let weak = metrics
        .iter()
        .all(|m| ((m.insn_per_cpu - r) / r).abs() <= WEAK_TOLERANCE);
    if weak {
        ScalingMode::Weak
    } else {
        ScalingMode::Strong
    }
}

/// Compute the scalability column for `m` against `reference`.
pub fn scalability(
    m: &RegionMetrics,
    reference: &RegionMetrics,
    mode: ScalingMode,
) -> Scalability {
    let insn_ref = reference.total_useful_instructions as f64;
    let insn = m.total_useful_instructions as f64;
    let instruction_scaling = match mode {
        // Weak: per-cpu instructions should stay constant.
        ScalingMode::Weak | ScalingMode::Comparison => {
            safe_ratio(reference.insn_per_cpu, m.insn_per_cpu)
        }
        // Strong: total instructions should stay constant.
        ScalingMode::Strong => safe_ratio(insn_ref, insn),
    };
    let ipc_scaling = safe_ratio(m.useful_ipc, reference.useful_ipc);
    let frequency_scaling =
        safe_ratio(m.frequency_ghz, reference.frequency_ghz);
    let computation_scalability =
        instruction_scaling * ipc_scaling * frequency_scaling;
    Scalability {
        instruction_scaling,
        ipc_scaling,
        frequency_scaling,
        computation_scalability,
        global_efficiency: m.parallel_efficiency * computation_scalability,
    }
}

fn safe_ratio(a: f64, b: f64) -> f64 {
    if b <= 0.0 || !a.is_finite() || !b.is_finite() {
        0.0
    } else {
        a / b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric(ncpus: u32, insn: u64, ipc: f64, freq: f64, pe: f64) -> RegionMetrics {
        RegionMetrics {
            ncpus,
            nranks: ncpus,
            nthreads: 1,
            elapsed_s: 1.0,
            total_useful_s: 1.0,
            total_useful_instructions: insn,
            total_useful_cycles: 1,
            parallel_efficiency: pe,
            mpi_parallel_efficiency: pe,
            mpi_communication_efficiency: 1.0,
            mpi_load_balance: 1.0,
            mpi_load_balance_in: 1.0,
            mpi_load_balance_inter: 1.0,
            omp_parallel_efficiency: 1.0,
            omp_load_balance: 1.0,
            omp_scheduling_efficiency: 1.0,
            omp_serialization_efficiency: 1.0,
            useful_ipc: ipc,
            frequency_ghz: freq,
            insn_per_cpu: insn as f64 / ncpus as f64,
        }
    }

    #[test]
    fn reference_is_least_resources() {
        let cfgs = vec![
            ResourceConfig::new(8, 56),
            ResourceConfig::new(2, 56),
            ResourceConfig::new(4, 56),
        ];
        assert_eq!(reference_index(&cfgs), 1);
    }

    #[test]
    fn reference_tie_breaks_on_ranks() {
        let cfgs = vec![
            ResourceConfig::new(112, 1),
            ResourceConfig::new(2, 56),
        ];
        assert_eq!(reference_index(&cfgs), 1);
    }

    #[test]
    fn strong_scaling_detected_when_total_insn_constant() {
        // total instructions constant -> per-cpu drops with cpus.
        let ms = vec![
            metric(112, 1_000_000, 1.0, 2.0, 0.9),
            metric(224, 1_000_000, 1.0, 2.0, 0.8),
        ];
        assert_eq!(detect_mode(&ms, 0), ScalingMode::Strong);
    }

    #[test]
    fn weak_scaling_detected_when_per_cpu_constant() {
        let ms = vec![
            metric(112, 1_000_000, 1.0, 2.0, 0.9),
            metric(448, 4_100_000, 1.0, 2.0, 0.85), // ~constant per cpu
        ];
        assert_eq!(detect_mode(&ms, 0), ScalingMode::Weak);
    }

    #[test]
    fn same_resources_is_comparison() {
        let ms = vec![
            metric(112, 1_000_000, 1.0, 2.0, 0.9),
            metric(112, 1_200_000, 1.0, 2.0, 0.9),
        ];
        assert_eq!(detect_mode(&ms, 0), ScalingMode::Comparison);
    }

    #[test]
    fn strong_scalability_factors() {
        let r = metric(112, 1_000_000, 1.0, 2.0, 0.9);
        // 2x cpus, 5% more instructions, ipc x3, freq x0.88
        let m = metric(224, 1_050_000, 3.0, 1.76, 0.8);
        let s = scalability(&m, &r, ScalingMode::Strong);
        assert!((s.instruction_scaling - 1.0 / 1.05).abs() < 1e-9);
        assert!((s.ipc_scaling - 3.0).abs() < 1e-9);
        assert!((s.frequency_scaling - 0.88).abs() < 1e-9);
        assert!(
            (s.computation_scalability
                - (1.0 / 1.05) * 3.0 * 0.88)
                .abs()
                < 1e-9
        );
        assert!((s.global_efficiency - 0.8 * s.computation_scalability).abs() < 1e-9);
    }

    #[test]
    fn weak_scalability_uses_per_cpu_instructions() {
        let r = metric(112, 1_000_000, 1.0, 2.0, 0.9);
        let m = metric(224, 2_400_000, 1.0, 2.0, 0.85); // 20% extra/cpu
        let s = scalability(&m, &r, ScalingMode::Weak);
        let per_cpu_ref = 1_000_000.0 / 112.0;
        let per_cpu_m = 2_400_000.0 / 224.0;
        assert!((s.instruction_scaling - per_cpu_ref / per_cpu_m).abs() < 1e-9);
    }

    #[test]
    fn reference_scales_to_one() {
        let r = metric(112, 1_000_000, 1.3, 2.1, 0.9);
        for mode in [ScalingMode::Weak, ScalingMode::Strong] {
            let s = scalability(&r, &r, mode);
            assert!((s.instruction_scaling - 1.0).abs() < 1e-12);
            assert!((s.ipc_scaling - 1.0).abs() < 1e-12);
            assert!((s.frequency_scaling - 1.0).abs() < 1e-12);
            assert!((s.global_efficiency - 0.9).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_reference_is_safe() {
        let r = metric(112, 0, 0.0, 0.0, 0.9);
        let m = metric(224, 10, 1.0, 1.0, 0.8);
        let s = scalability(&m, &r, ScalingMode::Strong);
        assert_eq!(s.ipc_scaling, 0.0);
        assert!(s.computation_scalability.is_finite());
    }
}
