//! Ablation — detection reliability vs platform noise (the paper's
//! §Discussion caveats: unstable platforms [6] and un-instrumented I/O
//! skew the factors; the detector's noise gate is the mitigation).
//!
//! Sweeps the simulator's noise model from calm to "misconfigured
//! platform" and reports, over many seeded histories with one injected
//! bug fix: true-positive rate (fix found at the right commit, with the
//! right explanation) and false-positive count (findings elsewhere).

use talp_pages::apps::{run_with_talp_noise, CodeVersion, Genex};
use talp_pages::pages::detect::{self, ChangeKind, DetectOptions};
use talp_pages::sim::{MachineSpec, NoiseModel, ResourceConfig};
use talp_pages::talp::{GitMeta, RunData};
use talp_pages::util::bench::Table;

fn history(noise: &NoiseModel, seed: u64) -> Vec<RunData> {
    let machine = MachineSpec::marenostrum5();
    let res = ResourceConfig::new(2, 14);
    let fix_at = 4;
    (0..8)
        .map(|i| {
            let version = if i < fix_at {
                CodeVersion::buggy()
            } else {
                CodeVersion::fixed()
            };
            let mut app = Genex::salpha(2, version);
            app.timesteps = 2;
            let (mut d, _) = run_with_talp_noise(
                &app,
                &machine,
                &res,
                seed * 100 + i,
                0,
                noise.clone(),
            );
            d.git = Some(GitMeta {
                commit: format!("c{i:07}"),
                branch: "main".into(),
                commit_timestamp: 1000 + i as i64,
                message: String::new(),
            });
            d
        })
        .collect()
}

fn main() {
    let noises: Vec<(&str, NoiseModel)> = vec![
        ("none", NoiseModel::none()),
        ("calm", NoiseModel::calm()),
        ("typical", NoiseModel::typical()),
        ("noisy [6]-style", NoiseModel::noisy()),
    ];
    let trials = 10u64;
    let mut table = Table::new(
        "Ablation — detection vs platform noise (8-commit history, fix at #4)",
        &["noise", "fix detected", "explained", "false positives/run"],
    );
    for (label, noise) in &noises {
        let mut detected = 0u32;
        let mut explained = 0u32;
        let mut false_pos = 0u32;
        for t in 0..trials {
            let runs = history(noise, t);
            let refs: Vec<&RunData> = runs.iter().collect();
            let findings =
                detect::detect("2x14", &refs, &DetectOptions::default());
            let mut hit = false;
            for f in &findings {
                let is_fix = f.region == "initialize"
                    && f.at_index == 4
                    && f.kind == ChangeKind::Improvement;
                if is_fix {
                    hit = true;
                    if f
                        .explanation
                        .as_ref()
                        .map(|(n, _, _)| n.contains("Serialization"))
                        .unwrap_or(false)
                    {
                        explained += 1;
                    }
                } else if f.region != "Global" {
                    // Global legitimately co-moves with initialize.
                    false_pos += 1;
                }
            }
            if hit {
                detected += 1;
            }
        }
        table.row(&[
            label.to_string(),
            format!("{detected}/{trials}"),
            format!("{explained}/{trials}"),
            format!("{:.1}", false_pos as f64 / trials as f64),
        ]);
        if *label != "noisy [6]-style" {
            assert_eq!(
                detected, trials as u32,
                "{label}: detector must be reliable below pathological noise"
            );
        }
    }
    table.print();
    println!(
        "\nShape: detection + explanation are robust through production-\n\
         level noise; only a [6]-style unstable platform degrades them —\n\
         matching the paper's call for instrumenting variance sources."
    );
}
