//! Table 7 — strong scaling-efficiency tables from all four tool chains
//! (TeaLeaf 4000^2 @ 2x56 -> 4x56).
//!
//! Reproduced claims: strong mode detected; super-linear IPC scaling
//! (paper 3.1-3.7x — the per-thread working set drops under the cache
//! share); frequency scaling < 1 (power limit at high IPC); instruction
//! scaling ~1; parallel efficiency degrades vs the reference; global
//! efficiency > 1 (super-linear computation wins over parallel losses).

use talp_pages::apps::TeaLeaf;
use talp_pages::pop::ScalingMode;
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::tools::{self, InstrumentedRun, ToolKind};
use talp_pages::util::fs::TempDir;

fn case() -> TeaLeaf {
    let mut t = TeaLeaf::with_grid(4000, 4000);
    t.timesteps = 2;
    t.cg_iters = 20;
    t.write_output = false;
    t
}

fn main() {
    let machine = MachineSpec::marenostrum5();
    let configs =
        [ResourceConfig::new(2, 56), ResourceConfig::new(4, 56)];
    for kind in ToolKind::all() {
        let td = TempDir::new("t7").unwrap();
        let app = case();
        let mut runs: Vec<InstrumentedRun> = Vec::new();
        for (i, cfg) in configs.iter().enumerate() {
            let dir = td.path().join(format!("{i}"));
            runs.push(
                tools::instrument(kind, &app, &machine, cfg, 13, 0, &dir)
                    .unwrap(),
            );
        }
        let refs: Vec<&InstrumentedRun> = runs.iter().collect();
        let (table, _) = tools::postprocess(kind, &refs, "Global").unwrap();
        let table = table.expect("table");
        println!("--- {} ---", kind.name());
        print!("{}", table.render_text());
        println!();

        if kind != ToolKind::Cpt {
            assert_eq!(table.mode, ScalingMode::Strong, "{}", kind.name());
        }
        if kind != ToolKind::Cpt {
            let ipc = table.cell("IPC scaling", 1).unwrap();
            assert!(
                (1.8..4.2).contains(&ipc),
                "{}: IPC scaling {ipc} outside the Table-7 band (paper 3.1-3.7)",
                kind.name()
            );
            let freq = table.cell("Frequency scaling", 1).unwrap();
            assert!(
                (0.80..0.99).contains(&freq),
                "{}: frequency scaling {freq} (paper 0.88-0.89)",
                kind.name()
            );
            let insn = table.cell("Instructions scaling", 1).unwrap();
            assert!(
                (0.93..1.07).contains(&insn),
                "{}: instruction scaling {insn} (paper 0.98-1.03)",
                kind.name()
            );
            let ge = table.cell("Global efficiency", 1).unwrap();
            assert!(
                ge > 1.0,
                "{}: global efficiency {ge} should be super-linear \
                 (paper 1.7-1.92)",
                kind.name()
            );
        }
        let pe0 = table.cell("Parallel efficiency", 0).unwrap();
        let pe1 = table.cell("Parallel efficiency", 1).unwrap();
        assert!(
            pe1 < pe0,
            "{}: PE should degrade ({pe0} -> {pe1})",
            kind.name()
        );
    }
    println!(
        "OK: strong mode, super-linear IPC + global efficiency, frequency\n\
         penalty, flat instructions, degrading parallel efficiency — the\n\
         Table 7 signature across all four chains."
    );
}
