//! Fig. 3 — the MPI-only strong-scaling table (112xMPI vs 224xMPI).
//!
//! Reproduced shape: global efficiency 0.9 -> ~0.8, driven by MPI
//! communication efficiency (load balance stays ~0.95+); instruction
//! scaling < 1 (halo packing overhead grows with ranks); IPC scaling ~1
//! (per-rank sets stay DRAM-resident); the compact table layout without
//! OpenMP rows.

use talp_pages::apps::{run_with_talp, MpiStencil};
use talp_pages::pop::{self, ScalingMode};
use talp_pages::sim::{MachineSpec, ResourceConfig};

fn main() {
    let machine = MachineSpec::marenostrum5();
    let app = MpiStencil::fig3();
    let (d112, _) =
        run_with_talp(&app, &machine, &ResourceConfig::new(112, 1), 21, 0);
    let (d224, _) =
        run_with_talp(&app, &machine, &ResourceConfig::new(224, 1), 21, 0);
    let table = pop::build("Global", &[&d112, &d224]).expect("table");
    print!("{}", table.render_text());

    assert_eq!(table.columns, vec!["112x1", "224x1"]);
    assert_eq!(table.mode, ScalingMode::Strong);
    assert!(
        table.rows.iter().all(|r| !r.label.contains("OpenMP")),
        "MPI-only layout must drop OpenMP rows"
    );
    let ge0 = table.cell("Global efficiency", 0).unwrap();
    let ge1 = table.cell("Global efficiency", 1).unwrap();
    assert!(ge0 > 0.8, "reference healthy: {ge0}");
    assert!(ge1 < ge0 - 0.05, "efficiency decays: {ge0} -> {ge1}");
    let insn = table.cell("Instructions scaling", 1).unwrap();
    assert!(
        (0.78..0.95).contains(&insn),
        "instruction scaling {insn} (paper 0.84)"
    );
    let lb = table.cell("MPI Load balance", 1).unwrap();
    assert!(lb > 0.9, "load balance stays healthy: {lb} (paper 0.96)");
    let pe1 = table.cell("Parallel efficiency", 1).unwrap();
    let comm1 = table.cell("MPI Communication efficiency", 1).unwrap();
    let comm0 = table.cell("MPI Communication efficiency", 0).unwrap();
    assert!(
        comm1 < comm0,
        "comm efficiency drives the decay: {comm0} -> {comm1}"
    );
    println!(
        "\nOK Fig. 3 shape: GE {ge0:.2}->{ge1:.2} (paper 0.90->0.79), \
         PE@224 {pe1:.2} (paper 0.80),\ninstr scaling {insn:.2} (paper \
         0.84), LB {lb:.2} (paper 0.96), comm-driven decay."
    );
}
