//! Table 6 — weak scaling-efficiency tables from all four tool chains
//! (TeaLeaf 4000^2@2x56 -> 8000^2@8x56).
//!
//! Reproduced claims: every chain detects *weak* scaling and agrees on
//! the parallel-efficiency hierarchy within a few points; the CPT column
//! has no computation-scalability rows (no hardware counters); only
//! BSC/CPT report the MPI serialization/transfer split; IPC and
//! frequency scaling stay ~1 under weak scaling (per-thread working set
//! unchanged).

use talp_pages::apps::TeaLeaf;
use talp_pages::pop::ScalingMode;
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::tools::{self, InstrumentedRun, ToolKind};
use talp_pages::util::fs::TempDir;

fn case(grid: u64) -> TeaLeaf {
    let mut t = TeaLeaf::with_grid(grid, grid);
    t.timesteps = 2;
    t.cg_iters = 20;
    t.write_output = false;
    t
}

fn main() {
    let machine = MachineSpec::marenostrum5();
    let configs = vec![
        (case(4000), ResourceConfig::new(2, 56)),
        (case(8000), ResourceConfig::new(8, 56)),
    ];
    let mut pe_by_tool = Vec::new();
    for kind in ToolKind::all() {
        let td = TempDir::new("t6").unwrap();
        let mut runs: Vec<InstrumentedRun> = Vec::new();
        for (i, (app, cfg)) in configs.iter().enumerate() {
            let dir = td.path().join(format!("{i}"));
            runs.push(
                tools::instrument(kind, app, &machine, cfg, 11, 0, &dir)
                    .unwrap(),
            );
        }
        let refs: Vec<&InstrumentedRun> = runs.iter().collect();
        let (table, _) = tools::postprocess(kind, &refs, "Global").unwrap();
        let table = table.expect("table");
        println!("--- {} ---", kind.name());
        print!("{}", table.render_text());
        println!();

        // Mode detection needs instruction counters, which the CPT does
        // not collect (its tables are labelled by experiment design).
        if kind != ToolKind::Cpt {
            assert_eq!(table.mode, ScalingMode::Weak, "{}", kind.name());
        }
        pe_by_tool.push((
            kind,
            table.cell("Parallel efficiency", 1).unwrap(),
            table.cell("IPC scaling", 1),
            table.cell("MPI Serialization efficiency", 1),
        ));
    }
    // Cross-tool agreement on PE at 8x56 (paper: 0.85-0.87).
    let reference = pe_by_tool[0].1;
    for (kind, pe, ipc, ser) in &pe_by_tool {
        assert!(
            (pe - reference).abs() < 0.06,
            "{} disagrees: {pe} vs {reference}",
            kind.name()
        );
        match kind {
            ToolKind::Cpt => {
                assert!(ipc.is_none(), "CPT must lack counters");
                assert!(ser.is_some(), "CPT has the comm split");
            }
            ToolKind::ExtraeBsc => {
                let i = ipc.expect("BSC has counters");
                assert!((0.85..1.25).contains(&i), "weak IPC scaling {i}");
                assert!(ser.is_some());
            }
            ToolKind::Talp | ToolKind::ScorepJsc => {
                let i = ipc.expect("counters present");
                assert!((0.85..1.25).contains(&i), "weak IPC scaling {i}");
                assert!(ser.is_none(), "no comm split without replay");
            }
        }
    }
    println!(
        "OK: all chains agree (PE@8x56 ~ {reference:.2}), weak mode detected,\n\
         CPT counter rows blank, BSC/CPT comm split present, IPC ~ 1."
    );
}
