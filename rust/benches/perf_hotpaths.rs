//! §Perf — micro-benchmarks of the hot paths (DESIGN.md §9):
//!   1. simulator event throughput (engine),
//!   2. TALP JSON parse throughput (report ingest): tree vs streaming,
//!   3. full report generation over a large history corpus,
//!   4. the store hot paths: a warm `report --store` over the 500-run
//!      corpus, the cold 5k-run shard load, and the indexed last-200
//!      query against its full-scan control,
//!   5. trace post-processing throughput (merge + dimemas replay).
//!
//! Targets: report of a 1k-run corpus < 1 s; simulator >= 1M events/s;
//! `RunData::from_slice` >= 2x the tree parse.  Every section emits a
//! machine-readable `BENCH_JSON {...}` line; CI compares each named
//! record against the previous run (`.github/scripts/bench_delta.py`)
//! with `benches/BENCH_hotpaths.json` as the committed seed baseline.

use talp_pages::apps::{self, run_with_talp, CodeVersion, Genex, TeaLeaf};
use talp_pages::pop::RunMetrics;
use talp_pages::session::{self, AnalyzeOptions, Session};
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::store::{ingest_dir, Admission, RunStore};
use talp_pages::talp::{GitMeta, RunData};
use talp_pages::tools::postprocess::{dimemas, merge};
use talp_pages::tools::resources::ResourceMeter;
use talp_pages::tools::tracer::ExtraeSink;
use talp_pages::util::bench::bench;
use talp_pages::util::fs::TempDir;
use talp_pages::util::json::Json;

fn main() {
    let machine = MachineSpec::marenostrum5();

    // 1. Simulator event throughput.
    let app = {
        let mut t = TeaLeaf::with_grid(1600, 1600);
        t.timesteps = 2;
        t.cg_iters = 30;
        t.write_output = false;
        t
    };
    let cfg = ResourceConfig::new(4, 28);
    let mut events = 0u64;
    let m = bench("sim: tealeaf 1600^2 4x28 clean run", 1, 8, || {
        let s = apps::run_clean(&app, &machine, &cfg, 1);
        events = s.total_events;
    });
    println!("{}", m.report());
    let eps = events as f64 / m.min_s;
    println!(
        "  -> {events} events / run = {:.2} M events/s (target >= 1 M/s)",
        eps / 1e6
    );
    assert!(eps > 1e6, "simulator below target: {eps}");

    // 2. TALP JSON parse throughput: the tree path vs the streaming
    //    `from_slice` path over the identical document.
    let (data, _) = run_with_talp(&app, &machine, &cfg, 2, 0);
    let text = data.to_json().to_string_pretty();
    let bytes = text.len() as f64;
    let m_tree = bench("talp json: parse+validate", 3, 200, || {
        let j = Json::parse(&text).unwrap();
        let r = RunData::from_json(&j).unwrap();
        std::hint::black_box(r.ranks);
    });
    println!("{}", m_tree.report());
    println!(
        "  -> {:.1} MB/s over {:.1} KB docs",
        bytes / m_tree.mean_s / 1e6,
        bytes / 1e3
    );
    let bench_path = std::path::Path::new("bench.json");
    let m_slice = bench("talp json: from_slice vs tree", 3, 200, || {
        let r = RunData::from_slice(text.as_bytes(), bench_path).unwrap();
        std::hint::black_box(r.ranks);
    });
    println!("{}", m_slice.report());
    println!(
        "  -> {:.1} MB/s, {:.2}x over the tree parse (target >= 2x)",
        bytes / m_slice.mean_s / 1e6,
        m_tree.min_s / m_slice.min_s.max(1e-12)
    );
    let record = Json::from_pairs(vec![
        ("bench", Json::Str("talp_json_parse".into())),
        ("doc_kb", Json::Num(bytes / 1e3)),
        ("tree_s", Json::Num(m_tree.min_s)),
        ("from_slice_s", Json::Num(m_slice.min_s)),
    ]);
    println!("BENCH_JSON {}", record.to_string_compact());

    // 3. Report generation over a large corpus: 2 experiments x 2
    //    configs x 125 commits = 500 runs.
    let td = TempDir::new("perf-corpus").unwrap();
    let mut g = Genex::salpha(1, CodeVersion::fixed());
    g.timesteps = 2;
    let configs = [ResourceConfig::new(2, 8), ResourceConfig::new(4, 8)];
    for exp in 0..2 {
        for cfg in &configs {
            let (base, _) = run_with_talp(&g, &machine, cfg, 9, 0);
            for i in 0..125 {
                let mut d = base.clone();
                d.timestamp = 1_700_000_000 + i * 3600;
                d.git = Some(GitMeta {
                    commit: format!("{exp:02}{i:06x}aaaaaaaa"),
                    branch: "main".into(),
                    commit_timestamp: d.timestamp,
                    message: String::new(),
                });
                d.write_file(
                    &td.path().join(format!(
                        "exp{exp}/runs/talp_{}_{i}.json",
                        cfg.label()
                    )),
                )
                .unwrap();
            }
        }
    }
    // Table 2's hot path, four ways: cold vs warm metrics cache and
    // sequential vs parallel workers.  The JSON line at the end is the
    // trackable record for future PRs (paper Table 2: report latency
    // under CI resource budgets).
    let out = TempDir::new("perf-out").unwrap();
    let cache_file = out.path().join(".talp-cache.json");
    let generate = |jobs: usize| {
        Session::new(td.path())
            .jobs(jobs)
            .cache(&cache_file)
            .scan()
            .unwrap()
            .analyze(&AnalyzeOptions::default())
            .emit(&mut session::default_emitters(out.path()))
            .unwrap()
    };

    let m_jobs1 = bench("report: 500-run corpus cold, --jobs 1", 0, 3, || {
        let _ = std::fs::remove_file(&cache_file);
        let s = generate(1);
        assert_eq!(s.cache_hits, 0, "cache must be cold");
        std::hint::black_box(s.pages_written);
    });
    println!("{}", m_jobs1.report());

    let m_cold = bench("report: 500-run corpus cold, --jobs auto", 0, 3, || {
        let _ = std::fs::remove_file(&cache_file);
        let s = generate(0);
        assert_eq!(s.cache_misses, 500, "corpus must fully parse");
        std::hint::black_box(s.pages_written);
    });
    println!("{}", m_cold.report());

    let m_warm = bench("report: 500-run corpus warm cache", 1, 5, || {
        let s = generate(0);
        assert_eq!(s.cache_misses, 0, "warm run must parse nothing");
        std::hint::black_box(s.pages_written);
    });
    println!("{}", m_warm.report());
    println!(
        "  -> cold/warm {:.2}x, jobs1/jobsN {:.2}x",
        m_cold.min_s.max(1e-9) / m_warm.min_s.max(1e-9),
        m_jobs1.min_s.max(1e-9) / m_cold.min_s.max(1e-9),
    );
    // Machine-readable line for cross-PR tracking (Table 2 metric).
    let record = Json::from_pairs(vec![
        ("bench", Json::Str("report_engine_500".into())),
        ("corpus_runs", Json::Num(500.0)),
        ("cold_jobs1_s", Json::Num(m_jobs1.min_s)),
        ("cold_auto_s", Json::Num(m_cold.min_s)),
        ("warm_s", Json::Num(m_warm.min_s)),
        (
            "jobs_auto",
            Json::Num(talp_pages::util::par::effective_jobs(0) as f64),
        ),
    ]);
    println!("BENCH_JSON {}", record.to_string_compact());
    assert!(
        m_cold.min_s < 1.0,
        "report generation target missed: {:.3}s for 500 runs",
        m_cold.min_s
    );
    assert!(
        m_warm.min_s <= m_jobs1.min_s * 1.5,
        "warm cache should never be drastically slower than a cold \
         sequential run ({:.3}s vs {:.3}s)",
        m_warm.min_s,
        m_jobs1.min_s
    );

    // 4a. Warm `report --store`: ingest the 500-run corpus once, then
    //     measure analyze+emit straight from the store (zero parsing —
    //     the path a dashboard pipeline hits on every commit).
    let sd = TempDir::new("perf-store").unwrap();
    let store_root = sd.path().join("store");
    {
        let mut store = RunStore::create_or_open(&store_root).unwrap();
        let rep = ingest_dir(&mut store, td.path()).unwrap();
        assert_eq!(rep.stored, 500, "corpus must fully ingest");
    }
    let store_out = TempDir::new("perf-store-out").unwrap();
    let m_store = bench("store: warm report --store (500)", 1, 5, || {
        let s = Session::from_store(&store_root)
            .scan()
            .unwrap()
            .analyze(&AnalyzeOptions::default())
            .emit(&mut session::default_emitters(store_out.path()))
            .unwrap();
        assert_eq!(s.cache_misses, 0, "store scans parse nothing");
        std::hint::black_box(s.pages_written);
    });
    println!("{}", m_store.report());
    let record = Json::from_pairs(vec![
        ("bench", Json::Str("report_store_500".into())),
        ("corpus_runs", Json::Num(500.0)),
        ("warm_s", Json::Num(m_store.min_s)),
    ]);
    println!("BENCH_JSON {}", record.to_string_compact());

    // 4b. Cold shard load at "thousands of stored runs" scale: 5k
    //     records across 10 experiments x 2 configs, timed through
    //     RunStore::open (parallel shard decode).
    let bd = TempDir::new("perf-store5k").unwrap();
    let big_root = bd.path().join("store");
    {
        let mut store = RunStore::create_or_open(&big_root).unwrap();
        let (base_run, _) =
            run_with_talp(&g, &machine, &configs[0], 7, 0);
        let mut batch = Vec::with_capacity(5000);
        for exp in 0..10u32 {
            for i in 0..500u32 {
                let mut d = base_run.clone();
                d.timestamp = 1_700_000_000 + i as i64 * 60;
                d.git = Some(GitMeta {
                    commit: format!("{exp:02x}{i:06x}bbbbbbbb"),
                    branch: "main".into(),
                    commit_timestamp: d.timestamp,
                    message: String::new(),
                });
                let source = format!("exp{exp}/runs/run_{i}.json");
                let rm = RunMetrics::from_run(&d, &source);
                batch.push((
                    format!("exp{exp}/runs"),
                    format!("{exp:04x}{i:08x}"),
                    rm,
                ));
            }
        }
        let appended = store.append_all(batch).unwrap();
        assert_eq!(appended, 5000, "5k distinct records must append");
    }
    let m_load = bench("store: cold load 5k-run shards", 0, 3, || {
        let s = RunStore::open(&big_root).unwrap();
        assert_eq!(s.len(), 5000);
        std::hint::black_box(s.len());
    });
    println!("{}", m_load.report());
    println!(
        "  -> {:.0} records/s",
        5000.0 / m_load.min_s.max(1e-12)
    );
    let record = Json::from_pairs(vec![
        ("bench", Json::Str("store_load_5k".into())),
        ("stored_runs", Json::Num(5000.0)),
        ("cold_load_s", Json::Num(m_load.min_s)),
    ]);
    println!("BENCH_JSON {}", record.to_string_compact());

    // 4c. Indexed query vs the full-scan control at the same scale —
    //     the index contract: decode only the selected tail, return
    //     byte-identical records.  (The CI store-scale job times the
    //     same pair through the CLI at 50k runs; this pins the
    //     correctness half at test scale.)
    {
        let s = RunStore::open(&big_root).unwrap();
        assert!(s.refresh_indexes().unwrap() > 0, "sidecars must write");
    }
    let spec = talp_pages::store::QuerySpec {
        experiment: Some("exp3/runs".into()),
        last: Some(200),
        ..Default::default()
    };
    let m_query = bench("store: indexed last-200 query (5k)", 1, 5, || {
        let out = RunStore::query(&big_root, 0, &spec).unwrap();
        assert_eq!(out.records.len(), 200);
        assert_eq!(
            out.stats.decoded_lines, 200,
            "an indexed query decodes only what it returns"
        );
        std::hint::black_box(out.records.len());
    });
    println!("{}", m_query.report());
    let indexed = RunStore::query(&big_root, 0, &spec).unwrap();
    let control = RunStore::query_full_scan(&big_root, 0, &spec).unwrap();
    assert_eq!(control.stats.decoded_lines, 5000, "the control is linear");
    let indexed_text: String =
        indexed.records.iter().map(|r| r.to_line() + "\n").collect();
    let control_text: String =
        control.records.iter().map(|r| r.to_line() + "\n").collect();
    assert_eq!(
        indexed_text, control_text,
        "indexed and full-scan results must be byte-identical"
    );
    println!(
        "  -> indexed {:.1}x the full scan ({} vs {} lines decoded)",
        control.stats.decoded_lines as f64
            / indexed.stats.decoded_lines.max(1) as f64,
        indexed.stats.decoded_lines,
        control.stats.decoded_lines
    );

    // 4d. Adapter admission throughput: 1000 BeeSwarm sweep files x 10
    //     scale points = 10k runs through the auto-detecting
    //     [`Admission`] path (hash, sniff, parse, normalize, append).
    let ad = TempDir::new("perf-adapters").unwrap();
    std::fs::create_dir_all(ad.path().join("bsw")).unwrap();
    for f in 0..1000u32 {
        let scales: String = (1..=10u32)
            .map(|p| {
                format!(
                    "{{\"processes\": {p}, \"threads\": 2, \"time_s\": \
                     {:.1}, \"efficiency\": 0.9}}",
                    10.0 + f as f64
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        let doc = format!(
            "{{\"application\": \"bsw\", \"machine\": \"mn5\", \
             \"timestamp\": \"2026-01-01T00:00:00Z\", \
             \"scales\": [{scales}]}}\n"
        );
        std::fs::write(
            ad.path().join(format!("bsw/sweep_{f:04}.json")),
            doc,
        )
        .unwrap();
    }
    let m_adapt = bench("adapters: auto-detect ingest 10k runs", 0, 3, || {
        let st = TempDir::new("perf-adapters-store").unwrap();
        let mut store =
            RunStore::create_or_open(&st.path().join("store")).unwrap();
        let rep = Admission::new().ingest_dir(&mut store, ad.path()).unwrap();
        assert_eq!(rep.stored, 10_000, "every scale point must admit");
        assert_eq!(rep.formats.get("beeswarm"), Some(&10_000));
        std::hint::black_box(rep.stored);
    });
    println!("{}", m_adapt.report());
    println!(
        "  -> {:.0} runs/s through the adapter registry",
        10_000.0 / m_adapt.min_s.max(1e-12)
    );
    let record = Json::from_pairs(vec![
        ("bench", Json::Str("adapter_ingest_10k".into())),
        ("corpus_runs", Json::Num(10_000.0)),
        ("ingest_s", Json::Num(m_adapt.min_s)),
    ]);
    println!("BENCH_JSON {}", record.to_string_compact());

    // 5. Trace post-processing throughput.
    let ttd = TempDir::new("perf-trace").unwrap();
    let small = {
        let mut t = TeaLeaf::with_grid(2000, 2000);
        t.timesteps = 1;
        t.cg_iters = 20;
        t.write_output = false;
        t
    };
    let tcfg = ResourceConfig::new(2, 28);
    {
        let prog_machine = machine.clone();
        let run_cfg = talp_pages::sim::RunConfig::new(
            prog_machine.clone(),
            tcfg.clone(),
        );
        let mut sink = ExtraeSink::create(ttd.path(), 2).unwrap();
        let prog = {
            use talp_pages::apps::Workload;
            small.build(&tcfg, &prog_machine)
        };
        talp_pages::sim::run(&prog, &run_cfg, &mut [&mut sink]);
        sink.finish(ttd.path()).unwrap();
    }
    let mut records = 0u64;
    let m = bench("postprocess: merge + dimemas replay", 1, 5, || {
        let mut meter = ResourceMeter::new();
        let trace = merge::load(ttd.path(), "prv", &mut meter).unwrap();
        let split =
            dimemas::replay(&trace, dimemas::NetworkModel::default(), &mut meter);
        records = split.replayed_events;
        std::hint::black_box(split.wait_s.len());
    });
    println!("{}", m.report());
    println!(
        "  -> {records} records = {:.2} M records/s",
        records as f64 / m.min_s / 1e6
    );

    // 6. Resident serve: one-run ingest + incremental re-analysis
    //    against the warm 5k-run corpus.  The incrementality contract
    //    (the serve `/statsz` witness): exactly ONE of the 10
    //    (experiment, config) histories recomputes per ingest; the 9
    //    untouched experiments ride along by reference.
    let mut monitor = talp_pages::serve::Monitor::open(
        &big_root,
        AnalyzeOptions::default(),
        0,
    )
    .unwrap();
    assert_eq!(monitor.stats().total_histories, 10);
    let (fresh_base, _) = run_with_talp(&g, &machine, &configs[0], 7, 0);
    let mut i = 0u32;
    let mut last_reanalyzed = 0usize;
    let m_serve =
        bench("serve: one-run ingest + reanalyze (5k warm)", 1, 5, || {
            let mut d = fresh_base.clone();
            d.timestamp = 1_700_400_000 + i as i64 * 60;
            d.git = Some(GitMeta {
                commit: format!("ff{i:06x}dddddddd"),
                branch: "main".into(),
                commit_timestamp: d.timestamp,
                message: String::new(),
            });
            let source = format!("exp0/runs/fresh_{i}.json");
            let rm = RunMetrics::from_run(&d, &source);
            let stored = monitor
                .ingest_run("exp0/runs", &format!("ffff{i:08x}"), rm)
                .unwrap();
            assert!(stored, "each bench iteration ingests unique content");
            let pass = monitor.refresh().unwrap().expect("dirty");
            assert_eq!(
                pass.reanalyzed_histories, 1,
                "a one-run ingest must not rescan unaffected histories"
            );
            assert_eq!(pass.reused_experiments, 9);
            last_reanalyzed = pass.reanalyzed_histories;
            i += 1;
        });
    println!("{}", m_serve.report());
    println!(
        "  -> reanalyzed {last_reanalyzed} of 10 histories per ingest"
    );
    let record = Json::from_pairs(vec![
        ("bench", Json::Str("serve_warm_reanalyze".into())),
        ("stored_runs", Json::Num(5000.0)),
        ("ingest_s", Json::Num(m_serve.min_s)),
        ("reanalyzed_histories", Json::Num(last_reanalyzed as f64)),
        ("total_histories", Json::Num(10.0)),
    ]);
    println!("BENCH_JSON {}", record.to_string_compact());
}
