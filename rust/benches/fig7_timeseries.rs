//! Fig. 7 — the GENE-X time-evolution case study: a 10-commit CI history
//! with the OpenMP-serialization bug fixed at commit 6; the report's
//! time-series must show the elapsed-time drop in `initialize` (and
//! Global), a flat `timestep`, flat computation counters, and the
//! OpenMP serialization efficiency as the explaining factor.

use talp_pages::ci::{CiEngine, MatrixSpec, PipelineOptions, Repo};
use talp_pages::pages::scan;
use talp_pages::pages::timeseries;
use talp_pages::session::AnalyzeOptions;
use talp_pages::util::bench::Table;
use talp_pages::util::fs::TempDir;

fn main() {
    let td = TempDir::new("fig7").unwrap();
    let n_commits = 10;
    let fix_at = 6;
    let repo = Repo::genex_history(n_commits, fix_at, 7, 1_700_000_000);
    let jobs = MatrixSpec {
        case: "salpha".into(),
        resolutions: vec![3],
        configurations: vec![("1Nx8MPI".into(), 8, 14)],
        machine_tags: vec!["mn5".into()],
    }
    .expand();
    let opts = PipelineOptions {
        analyze: AnalyzeOptions {
            regions: vec!["initialize".into(), "timestep".into()],
            region_for_badge: Some("timestep".into()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut engine = CiEngine::new(td.path()).unwrap();
    let mut report_times = Vec::new();
    for commit in &repo.commits {
        let r = engine.run_pipeline(commit, &jobs, &opts).unwrap();
        report_times.push(r.wall_time_s);
    }

    // Rebuild the series from the *published* talp folder, exactly as
    // the report generator does.
    let talp_dir = talp_pages::util::fs::subdirs(&td.path().join("work"))
        .last()
        .unwrap()
        .join("talp");
    let scanres = scan(&talp_dir).unwrap();
    let exp = &scanres.experiments[0];
    let cfg = exp.configs()[0].clone();
    let history = exp.history_for_config(&cfg);
    assert_eq!(history.len(), n_commits);
    let ts = timeseries::build(&cfg, &history, &[]);

    let mut table = Table::new(
        "Fig. 7 — initialize region across commits",
        &["commit", "elapsed [s]", "IPC", "freq [GHz]", "OMP serial eff"],
    );
    let elapsed = ts.metric("initialize", "elapsed");
    let ipc = ts.metric("initialize", "ipc");
    let freq = ts.metric("initialize", "frequency");
    let ser = ts.metric("initialize", "omp_serialization_efficiency");
    for i in 0..n_commits {
        table.row(&[
            format!(
                "{}{}",
                repo.commits[i].short(),
                if i == fix_at { "  <- FIX" } else { "" }
            ),
            format!("{:.4}", elapsed[i].1),
            format!("{:.2}", ipc[i].1),
            format!("{:.2}", freq[i].1),
            format!("{:.2}", ser[i].1),
        ]);
    }
    table.print();

    // --- the Fig. 7 assertions ---
    let before = elapsed[fix_at - 1].1;
    let after = elapsed[fix_at].1;
    assert!(
        after < 0.7 * before,
        "initialize elapsed must drop at the fix: {before} -> {after}"
    );
    let g = ts.metric("Global", "elapsed");
    assert!(g[fix_at].1 < g[fix_at - 1].1, "Global drops too");
    let t = ts.metric("timestep", "elapsed");
    let rel_t = (t[fix_at].1 - t[fix_at - 1].1).abs() / t[fix_at - 1].1;
    assert!(rel_t < 0.1, "timestep unaffected ({rel_t})");
    let rel_ipc =
        (ipc[fix_at].1 - ipc[fix_at - 1].1).abs() / ipc[fix_at - 1].1;
    assert!(rel_ipc < 0.15, "IPC must stay flat ({rel_ipc})");
    let insn = ts.metric("initialize", "instructions");
    let rel_insn =
        (insn[fix_at].1 - insn[fix_at - 1].1).abs() / insn[fix_at - 1].1;
    assert!(rel_insn < 0.05, "instructions must stay flat ({rel_insn})");
    assert!(
        ser[fix_at].1 > ser[fix_at - 1].1 + 0.15,
        "OMP serialization efficiency explains the change: {} -> {}",
        ser[fix_at - 1].1,
        ser[fix_at].1
    );
    let mean_report =
        report_times.iter().sum::<f64>() / report_times.len() as f64;
    println!(
        "\nOK Fig. 7: drop at {} explained by OMP serialization efficiency\n\
         ({:.2} -> {:.2}) with flat IPC/instructions/frequency.\n\
         Mean pipeline wall time (run+accumulate+report): {:.2}s.",
        repo.commits[fix_at].short(),
        ser[fix_at - 1].1,
        ser[fix_at].1,
        mean_report
    );
}
