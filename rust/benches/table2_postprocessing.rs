//! Table 2 — minimum resource requirements (memory, storage, time) for
//! each tool chain to produce the scaling-efficiency table.
//!
//! Weak experiment: 4000^2@2x56 + 8000^2@8x56.  Strong experiment:
//! 4000^2@{2x56, 4x56}.  As in the paper the CPT row is shown but its
//! post-processing is only "copying files together".
//!
//! Scale note (DESIGN.md §2): we run ~40 CG iterations instead of the
//! paper's thousands, so absolute bytes/seconds are ~100x smaller; the
//! orders-of-magnitude *ratios* between chains are the reproduced claim.

use talp_pages::apps::TeaLeaf;
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::tools::{self, InstrumentedRun, ToolKind};
use talp_pages::util::bench::Table;
use talp_pages::util::fs::TempDir;
use talp_pages::util::stats::{fmt_bytes, fmt_duration};

fn case(grid: u64) -> TeaLeaf {
    let mut t = TeaLeaf::with_grid(grid, grid);
    t.timesteps = 2;
    t.cg_iters = 20;
    t.write_output = false;
    t
}

fn paper(kind: ToolKind) -> [&'static str; 6] {
    // mem weak, mem strong, storage weak, storage strong, time weak/strong
    match kind {
        ToolKind::Talp => ["0.13GB", "0.13GB", "0.02GB", "0.02GB", "2s", "2s"],
        ToolKind::ScorepJsc => ["44GB", "19GB", "29GB", "6.7GB", "436s", "441s"],
        ToolKind::ExtraeBsc => {
            ["138GB", "32GB", "165GB", "49GB", "10800s", "3030s"]
        }
        ToolKind::Cpt => ["(manual)", "-", "-", "-", "-", "-"],
    }
}

fn main() {
    let machine = MachineSpec::marenostrum5();
    let experiments: Vec<(&str, Vec<(TeaLeaf, ResourceConfig)>)> = vec![
        (
            "weak",
            vec![
                (case(4000), ResourceConfig::new(2, 56)),
                (case(8000), ResourceConfig::new(8, 56)),
            ],
        ),
        (
            "strong",
            vec![
                (case(4000), ResourceConfig::new(2, 56)),
                (case(4000), ResourceConfig::new(4, 56)),
            ],
        ),
    ];

    let mut table = Table::new(
        "Table 2 — post-processing floor (measured | paper)",
        &["tool", "scaling", "memory", "storage", "time"],
    );
    let mut talp_mem = 1u64;
    let mut bsc_mem = 1u64;
    let mut talp_sto = 1u64;
    let mut bsc_sto = 1u64;
    for kind in ToolKind::all() {
        for (exp_i, (label, configs)) in experiments.iter().enumerate() {
            let td = TempDir::new("t2").unwrap();
            let mut runs: Vec<InstrumentedRun> = Vec::new();
            for (i, (app, cfg)) in configs.iter().enumerate() {
                let dir = td.path().join(format!("{i}"));
                runs.push(
                    tools::instrument(kind, app, &machine, cfg, 5, 0, &dir)
                        .unwrap(),
                );
            }
            let refs: Vec<&InstrumentedRun> = runs.iter().collect();
            let (tbl, usage) =
                tools::postprocess(kind, &refs, "Global").unwrap();
            assert!(tbl.is_some(), "{} produced no table", kind.name());
            let p = paper(kind);
            table.row(&[
                kind.name().to_string(),
                label.to_string(),
                format!("{} | {}", fmt_bytes(usage.peak_memory_bytes), p[exp_i]),
                format!(
                    "{} | {}",
                    fmt_bytes(usage.storage_bytes),
                    p[2 + exp_i]
                ),
                format!(
                    "{} | {}",
                    fmt_duration(usage.wall_time_s),
                    p[4 + exp_i]
                ),
            ]);
            if exp_i == 0 {
                match kind {
                    ToolKind::Talp => {
                        talp_mem = usage.peak_memory_bytes.max(1);
                        talp_sto = usage.storage_bytes.max(1);
                    }
                    ToolKind::ExtraeBsc => {
                        bsc_mem = usage.peak_memory_bytes.max(1);
                        bsc_sto = usage.storage_bytes.max(1);
                    }
                    _ => {}
                }
            }
        }
    }
    table.print();
    println!(
        "\nHeadline ratios (weak): BSC/TALP memory {}x, storage {}x\n\
         (paper: ~1000x and ~8000x — trace chains need orders of magnitude\n\
         more of everything; TALP already reduced during the run).",
        bsc_mem / talp_mem,
        bsc_sto / talp_sto
    );
    assert!(bsc_mem / talp_mem > 50, "memory ratio collapsed");
    assert!(bsc_sto / talp_sto > 50, "storage ratio collapsed");
}
