//! Table 1 — runtime overhead of DLB/TALP, CPT, Score-P and Extrae on
//! the TeaLeaf CG benchmark (paper §Runtime Overhead).
//!
//! Configurations match the paper: 4000^2 at 2x56 (reference), 4000^2 at
//! 4x56 (the strong-scaled worst case) and 8000^2 at 8x56 (weak-scaled),
//! all on the MareNostrum-5 machine model.  CG iteration counts are
//! scaled down (the overhead ratio is per-chunk-cost / chunk-duration,
//! independent of iteration count); 3 repetitions give the stddev the
//! paper quotes next to the runtimes.

use talp_pages::apps::{self, TeaLeaf};
use talp_pages::sim::{MachineSpec, NoiseModel, ResourceConfig};
use talp_pages::tools::{self, ToolKind};
use talp_pages::util::bench::Table;
use talp_pages::util::fs::TempDir;
use talp_pages::util::stats::Welford;

/// Paper values for the "expected shape" column.
fn paper_value(kind: ToolKind, row: usize) -> &'static str {
    match (kind, row) {
        (ToolKind::Talp, 0) => "4.7%",
        (ToolKind::Talp, 1) => "22%",
        (ToolKind::Talp, 2) => "5.9%",
        (ToolKind::Cpt, 0) => "2.5%",
        (ToolKind::Cpt, 1) => "14%",
        (ToolKind::Cpt, 2) => "4.1%",
        (ToolKind::ScorepJsc, 0) => "2.4%",
        (ToolKind::ScorepJsc, 1) => "11%",
        (ToolKind::ScorepJsc, 2) => "3.3%",
        (ToolKind::ExtraeBsc, 0) => "5.4%",
        (ToolKind::ExtraeBsc, 1) => "23%",
        (ToolKind::ExtraeBsc, 2) => "7.8%",
        _ => "?",
    }
}

fn case(grid: u64, iters: u32) -> TeaLeaf {
    let mut t = TeaLeaf::with_grid(grid, grid);
    t.timesteps = 2;
    t.cg_iters = iters;
    t.write_output = false; // overhead of compute+MPI, as in the paper
    t
}

fn main() {
    let machine = MachineSpec::marenostrum5();
    let rows: Vec<(&str, TeaLeaf, ResourceConfig)> = vec![
        ("4000^2 2x56", case(4000, 12), ResourceConfig::new(2, 56)),
        ("4000^2 4x56", case(4000, 12), ResourceConfig::new(4, 56)),
        ("8000^2 8x56", case(8000, 12), ResourceConfig::new(8, 56)),
    ];
    let reps = 3u64;

    let mut table = Table::new(
        "Table 1 — runtime overhead (measured | paper)",
        &[
            "case", "clean [s]", "(stddev)", "DLB", "CPT", "Score-P",
            "Extrae",
        ],
    );
    for (row_idx, (label, app, cfg)) in rows.iter().enumerate() {
        // Clean runtime across seeds (the paper's "runtime (stddev)").
        let mut clean = Welford::new();
        for seed in 0..reps {
            let s = apps::workload::run_clean_noisy(
                app,
                &machine,
                cfg,
                seed,
                NoiseModel::typical(),
            );
            clean.push(s.elapsed_s);
        }
        let mut cells = vec![
            label.to_string(),
            format!("{:.2}", clean.mean()),
            format!("({:.1}%)", clean.rel_stddev() * 100.0),
        ];
        for kind in [
            ToolKind::Talp,
            ToolKind::Cpt,
            ToolKind::ScorepJsc,
            ToolKind::ExtraeBsc,
        ] {
            let mut oh = Welford::new();
            for seed in 0..reps {
                let td = TempDir::new("t1").unwrap();
                let run = tools::instrument(
                    kind,
                    app,
                    &machine,
                    cfg,
                    seed,
                    0,
                    td.path(),
                )
                .unwrap();
                oh.push(run.overhead_fraction() * 100.0);
            }
            cells.push(format!(
                "{:.1}% | {}",
                oh.mean(),
                paper_value(kind, row_idx)
            ));
        }
        table.row(&cells);
    }
    table.print();
    println!(
        "\nShape checks: CPT ~ Score-P < DLB < Extrae per row; the 4x56\n\
         strong-scaled row is the worst case for every tool (fine OpenMP\n\
         granularity + cache-resident rows), weak scaling stays benign."
    );
}
