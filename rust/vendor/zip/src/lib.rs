//! Offline drop-in subset of the `zip` crate.
//!
//! The build image has no crates.io registry, so this vendored
//! implementation provides the surface `talp-pages` uses — writing a
//! directory tree into a `.zip` and reading it back — on top of the real
//! ZIP container format (PKWARE APPNOTE): local file headers, a central
//! directory and the end-of-central-directory record, so the artifacts
//! are valid archives any `unzip` can open.
//!
//! One deliberate restriction: entries are always **STORED**
//! (uncompressed).  Requesting [`CompressionMethod::Deflated`] is
//! accepted for API compatibility but falls back to STORED — the CI
//! artifact tests measure relative sizes, not ratios, and a DEFLATE
//! codec is not worth vendoring.

use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Component, PathBuf};

pub mod result {
    use std::fmt;

    /// Errors from reading or writing an archive.
    #[derive(Debug)]
    pub enum ZipError {
        Io(std::io::Error),
        InvalidArchive(&'static str),
        UnsupportedArchive(&'static str),
        FileNotFound,
    }

    impl fmt::Display for ZipError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            match self {
                ZipError::Io(e) => write!(f, "zip io error: {e}"),
                ZipError::InvalidArchive(m) => {
                    write!(f, "invalid zip archive: {m}")
                }
                ZipError::UnsupportedArchive(m) => {
                    write!(f, "unsupported zip archive: {m}")
                }
                ZipError::FileNotFound => write!(f, "file not found in zip"),
            }
        }
    }

    impl std::error::Error for ZipError {
        fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
            match self {
                ZipError::Io(e) => Some(e),
                _ => None,
            }
        }
    }

    impl From<std::io::Error> for ZipError {
        fn from(e: std::io::Error) -> ZipError {
            ZipError::Io(e)
        }
    }

    pub type ZipResult<T> = Result<T, ZipError>;
}

pub use result::{ZipError, ZipResult};

/// Entry compression method.  Only STORED is actually produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionMethod {
    Stored,
    /// Accepted for compatibility; falls back to STORED on write.
    Deflated,
}

pub mod write {
    use super::CompressionMethod;

    /// Per-file options for [`super::ZipWriter::start_file`].
    #[derive(Debug, Clone, Copy)]
    pub struct FileOptions {
        pub(crate) _method: CompressionMethod,
    }

    impl Default for FileOptions {
        fn default() -> FileOptions {
            FileOptions { _method: CompressionMethod::Stored }
        }
    }

    impl FileOptions {
        /// Request a compression method (DEFLATE requests fall back to
        /// STORED — see the crate docs).
        pub fn compression_method(
            mut self,
            method: CompressionMethod,
        ) -> FileOptions {
            self._method = method;
            self
        }
    }
}

const LOCAL_SIG: u32 = 0x0403_4b50;
const CENTRAL_SIG: u32 = 0x0201_4b50;
const EOCD_SIG: u32 = 0x0605_4b50;
/// DOS date 1980-01-01 (month 1, day 1) — a fixed, valid timestamp so
/// archives are byte-reproducible.
const DOS_DATE: u16 = 0x0021;

/// IEEE CRC-32 (reflected, poly 0xEDB88320) over `data`.
fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn u16le(v: u16) -> [u8; 2] {
    v.to_le_bytes()
}

fn u32le(v: u32) -> [u8; 4] {
    v.to_le_bytes()
}

struct CentralEntry {
    name: String,
    crc: u32,
    size: u32,
    local_offset: u32,
}

struct PendingFile {
    name: String,
    data: Vec<u8>,
}

/// Streams files into a ZIP archive (STORED entries).
pub struct ZipWriter<W: Write> {
    inner: W,
    offset: u64,
    entries: Vec<CentralEntry>,
    current: Option<PendingFile>,
}

impl<W: Write> ZipWriter<W> {
    pub fn new(inner: W) -> ZipWriter<W> {
        ZipWriter { inner, offset: 0, entries: Vec::new(), current: None }
    }

    /// Begin a new entry; subsequent [`Write`] calls append to it.
    pub fn start_file<S: Into<String>>(
        &mut self,
        name: S,
        _options: write::FileOptions,
    ) -> ZipResult<()> {
        self.flush_pending()?;
        self.current =
            Some(PendingFile { name: name.into(), data: Vec::new() });
        Ok(())
    }

    fn emit(&mut self, bytes: &[u8]) -> ZipResult<()> {
        self.inner.write_all(bytes)?;
        self.offset += bytes.len() as u64;
        Ok(())
    }

    /// Write the buffered entry: local header + name + stored data.
    fn flush_pending(&mut self) -> ZipResult<()> {
        let Some(file) = self.current.take() else {
            return Ok(());
        };
        if file.data.len() > u32::MAX as usize
            || self.offset > u32::MAX as u64
        {
            return Err(ZipError::UnsupportedArchive(
                "zip64 archives not supported",
            ));
        }
        let crc = crc32(&file.data);
        let size = file.data.len() as u32;
        let local_offset = self.offset as u32;
        let name_bytes = file.name.as_bytes().to_vec();

        let mut header = Vec::with_capacity(30 + name_bytes.len());
        header.extend_from_slice(&u32le(LOCAL_SIG));
        header.extend_from_slice(&u16le(20)); // version needed
        header.extend_from_slice(&u16le(0)); // flags
        header.extend_from_slice(&u16le(0)); // method: STORED
        header.extend_from_slice(&u16le(0)); // mod time
        header.extend_from_slice(&u16le(DOS_DATE)); // mod date
        header.extend_from_slice(&u32le(crc));
        header.extend_from_slice(&u32le(size)); // compressed
        header.extend_from_slice(&u32le(size)); // uncompressed
        header.extend_from_slice(&u16le(name_bytes.len() as u16));
        header.extend_from_slice(&u16le(0)); // extra len
        header.extend_from_slice(&name_bytes);
        self.emit(&header)?;
        self.emit(&file.data)?;
        self.entries.push(CentralEntry {
            name: file.name,
            crc,
            size,
            local_offset,
        });
        Ok(())
    }

    /// Write the central directory and EOCD; returns the inner writer.
    pub fn finish(mut self) -> ZipResult<W> {
        self.flush_pending()?;
        let cd_offset = self.offset;
        let mut cd = Vec::with_capacity(self.entries.len() * 64);
        for e in &self.entries {
            let name_bytes = e.name.as_bytes();
            cd.extend_from_slice(&u32le(CENTRAL_SIG));
            cd.extend_from_slice(&u16le(20)); // version made by
            cd.extend_from_slice(&u16le(20)); // version needed
            cd.extend_from_slice(&u16le(0)); // flags
            cd.extend_from_slice(&u16le(0)); // method: STORED
            cd.extend_from_slice(&u16le(0)); // mod time
            cd.extend_from_slice(&u16le(DOS_DATE)); // mod date
            cd.extend_from_slice(&u32le(e.crc));
            cd.extend_from_slice(&u32le(e.size)); // compressed
            cd.extend_from_slice(&u32le(e.size)); // uncompressed
            cd.extend_from_slice(&u16le(name_bytes.len() as u16));
            cd.extend_from_slice(&u16le(0)); // extra len
            cd.extend_from_slice(&u16le(0)); // comment len
            cd.extend_from_slice(&u16le(0)); // disk number
            cd.extend_from_slice(&u16le(0)); // internal attrs
            cd.extend_from_slice(&u32le(0)); // external attrs
            cd.extend_from_slice(&u32le(e.local_offset));
            cd.extend_from_slice(name_bytes);
        }
        self.emit(&cd)?;
        let cd_size = self.offset - cd_offset;
        if cd_offset > u32::MAX as u64 || self.entries.len() > u16::MAX as usize
        {
            return Err(ZipError::UnsupportedArchive(
                "zip64 archives not supported",
            ));
        }
        let n = self.entries.len() as u16;
        let mut eocd = Vec::with_capacity(22);
        eocd.extend_from_slice(&u32le(EOCD_SIG));
        eocd.extend_from_slice(&u16le(0)); // this disk
        eocd.extend_from_slice(&u16le(0)); // cd start disk
        eocd.extend_from_slice(&u16le(n)); // entries on this disk
        eocd.extend_from_slice(&u16le(n)); // entries total
        eocd.extend_from_slice(&u32le(cd_size as u32));
        eocd.extend_from_slice(&u32le(cd_offset as u32));
        eocd.extend_from_slice(&u16le(0)); // comment len
        self.emit(&eocd)?;
        self.inner.flush()?;
        Ok(self.inner)
    }
}

impl<W: Write> Write for ZipWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match &mut self.current {
            Some(file) => {
                file.data.extend_from_slice(buf);
                Ok(buf.len())
            }
            None => Err(io::Error::new(
                io::ErrorKind::Other,
                "ZipWriter: write before start_file",
            )),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[derive(Debug, Clone)]
struct ArchiveEntry {
    name: String,
    method: u16,
    compressed_size: u32,
    local_offset: u32,
}

/// Reads a ZIP archive's central directory and serves entries.
pub struct ZipArchive<R: Read + Seek> {
    reader: R,
    entries: Vec<ArchiveEntry>,
}

fn rd_u16(buf: &[u8], at: usize) -> Option<u16> {
    Some(u16::from_le_bytes([*buf.get(at)?, *buf.get(at + 1)?]))
}

fn rd_u32(buf: &[u8], at: usize) -> Option<u32> {
    Some(u32::from_le_bytes([
        *buf.get(at)?,
        *buf.get(at + 1)?,
        *buf.get(at + 2)?,
        *buf.get(at + 3)?,
    ]))
}

impl<R: Read + Seek> ZipArchive<R> {
    pub fn new(mut reader: R) -> ZipResult<ZipArchive<R>> {
        let file_len = reader.seek(SeekFrom::End(0))?;
        // EOCD is 22 bytes + up to 64 KiB of comment; scan the tail.
        let tail_len = file_len.min(22 + 65_536);
        reader.seek(SeekFrom::Start(file_len - tail_len))?;
        let mut tail = vec![0u8; tail_len as usize];
        reader.read_exact(&mut tail)?;
        let sig = u32le(EOCD_SIG);
        let eocd_at = (0..tail.len().saturating_sub(21))
            .rev()
            .find(|&i| tail[i..i + 4] == sig)
            .ok_or(ZipError::InvalidArchive("no end-of-central-directory"))?;
        let eocd = &tail[eocd_at..];
        let count = rd_u16(eocd, 10)
            .ok_or(ZipError::InvalidArchive("truncated EOCD"))?
            as usize;
        let cd_size = rd_u32(eocd, 12)
            .ok_or(ZipError::InvalidArchive("truncated EOCD"))?
            as usize;
        let cd_offset = rd_u32(eocd, 16)
            .ok_or(ZipError::InvalidArchive("truncated EOCD"))?
            as u64;

        reader.seek(SeekFrom::Start(cd_offset))?;
        let mut cd = vec![0u8; cd_size];
        reader.read_exact(&mut cd)?;
        let mut entries = Vec::with_capacity(count);
        let mut pos = 0usize;
        for _ in 0..count {
            let bad =
                || ZipError::InvalidArchive("bad central directory entry");
            if rd_u32(&cd, pos) != Some(CENTRAL_SIG) {
                return Err(bad());
            }
            let method = rd_u16(&cd, pos + 10).ok_or_else(bad)?;
            let compressed_size = rd_u32(&cd, pos + 20).ok_or_else(bad)?;
            let name_len = rd_u16(&cd, pos + 28).ok_or_else(bad)? as usize;
            let extra_len = rd_u16(&cd, pos + 30).ok_or_else(bad)? as usize;
            let comment_len = rd_u16(&cd, pos + 32).ok_or_else(bad)? as usize;
            let local_offset = rd_u32(&cd, pos + 42).ok_or_else(bad)?;
            let name_bytes = cd
                .get(pos + 46..pos + 46 + name_len)
                .ok_or_else(bad)?;
            let name = String::from_utf8_lossy(name_bytes).into_owned();
            entries.push(ArchiveEntry {
                name,
                method,
                compressed_size,
                local_offset,
            });
            pos += 46 + name_len + extra_len + comment_len;
        }
        Ok(ZipArchive { reader, entries })
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Open entry `index` for reading.
    pub fn by_index(&mut self, index: usize) -> ZipResult<ZipFile<'_, R>> {
        let entry = self
            .entries
            .get(index)
            .cloned()
            .ok_or(ZipError::FileNotFound)?;
        if entry.method != 0 {
            return Err(ZipError::UnsupportedArchive(
                "only STORED entries supported",
            ));
        }
        self.reader.seek(SeekFrom::Start(entry.local_offset as u64))?;
        let mut local = [0u8; 30];
        self.reader.read_exact(&mut local)?;
        if rd_u32(&local, 0) != Some(LOCAL_SIG) {
            return Err(ZipError::InvalidArchive("bad local file header"));
        }
        let name_len = rd_u16(&local, 26).unwrap_or(0) as u64;
        let extra_len = rd_u16(&local, 28).unwrap_or(0) as u64;
        self.reader.seek(SeekFrom::Current((name_len + extra_len) as i64))?;
        let take = (&mut self.reader).take(entry.compressed_size as u64);
        Ok(ZipFile { name: entry.name, reader: take })
    }
}

/// One readable entry of an archive.
pub struct ZipFile<'a, R: Read> {
    name: String,
    reader: io::Take<&'a mut R>,
}

impl<'a, R: Read> ZipFile<'a, R> {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Directory entries carry a trailing slash by convention.
    pub fn is_dir(&self) -> bool {
        self.name.ends_with('/')
    }

    /// The entry name as a safe relative path (no absolute paths, no
    /// `..` traversal), like the upstream crate's zip-slip guard.
    pub fn enclosed_name(&self) -> Option<PathBuf> {
        let path = PathBuf::from(&self.name);
        if path.is_absolute() {
            return None;
        }
        for comp in path.components() {
            match comp {
                Component::Normal(_) | Component::CurDir => {}
                _ => return None,
            }
        }
        Some(path)
    }
}

impl<'a, R: Read> Read for ZipFile<'a, R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        self.reader.read(buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn build(names: &[(&str, &[u8])]) -> Vec<u8> {
        let mut zw = ZipWriter::new(Cursor::new(Vec::new()));
        let opts = write::FileOptions::default()
            .compression_method(CompressionMethod::Deflated);
        for (name, data) in names {
            zw.start_file(*name, opts).unwrap();
            zw.write_all(data).unwrap();
        }
        zw.finish().unwrap().into_inner()
    }

    #[test]
    fn roundtrip_multiple_entries() {
        let bytes = build(&[
            ("a/b/one.json", b"{\"x\":1}"),
            ("two.txt", b"hello world"),
            ("empty", b""),
        ]);
        let mut ar = ZipArchive::new(Cursor::new(bytes)).unwrap();
        assert_eq!(ar.len(), 3);
        let mut seen = Vec::new();
        for i in 0..ar.len() {
            let mut f = ar.by_index(i).unwrap();
            let mut data = Vec::new();
            f.read_to_end(&mut data).unwrap();
            seen.push((f.name().to_string(), data));
        }
        assert_eq!(seen[0], ("a/b/one.json".to_string(), b"{\"x\":1}".to_vec()));
        assert_eq!(seen[1].1, b"hello world".to_vec());
        assert!(seen[2].1.is_empty());
    }

    #[test]
    fn enclosed_name_rejects_traversal() {
        let bytes = build(&[("../evil", b"x"), ("ok/fine.txt", b"y")]);
        let mut ar = ZipArchive::new(Cursor::new(bytes)).unwrap();
        assert!(ar.by_index(0).unwrap().enclosed_name().is_none());
        assert_eq!(
            ar.by_index(1).unwrap().enclosed_name().unwrap(),
            PathBuf::from("ok/fine.txt")
        );
    }

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(ZipArchive::new(Cursor::new(b"not a zip".to_vec())).is_err());
        assert!(ZipArchive::new(Cursor::new(Vec::new())).is_err());
    }

    #[test]
    fn deterministic_output() {
        let a = build(&[("x.json", b"{}"), ("y.json", b"[]")]);
        let b = build(&[("x.json", b"{}"), ("y.json", b"[]")]);
        assert_eq!(a, b);
    }
}
