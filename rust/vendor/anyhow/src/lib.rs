//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build image carries no crates.io registry, so this vendored
//! re-implementation provides exactly the surface `talp-pages` uses:
//!
//! * [`Error`] — a context-chain error (no backtraces, no downcasting);
//! * [`Result<T>`] — `Result<T, Error>` alias;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Display semantics match upstream where it matters to callers:
//! `{}` prints the outermost message, `{:#}` prints the whole chain
//! joined by `": "`, and `{:?}` prints the chain as a "Caused by" list.

use std::fmt::{self, Debug, Display};

/// A context-chain error.  `chain[0]` is the outermost (most recently
/// attached) message, the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (what `anyhow!` expands
    /// to).
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with one more layer of context (outermost).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &self.chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps the blanket conversion below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;
    use std::fmt::Display;

    /// Sealed helper so [`super::Context`] covers both `Result<T, E>`
    /// with a std error and `Result<T, anyhow::Error>`.
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e = Error::from(io_err());
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading x").unwrap_err();
        let e = Err::<(), Error>(e).with_context(|| "scanning").unwrap_err();
        assert_eq!(format!("{e}"), "scanning");
        assert_eq!(format!("{e:#}"), "scanning: reading x: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn inner(fail: bool, n: u32) -> Result<u32> {
            ensure!(n > 0, "n must be positive, got {n}");
            if fail {
                bail!("failed with {}", n);
            }
            Ok(n)
        }
        assert_eq!(inner(false, 2).unwrap(), 2);
        assert_eq!(inner(true, 2).unwrap_err().to_string(), "failed with 2");
        assert_eq!(
            inner(false, 0).unwrap_err().to_string(),
            "n must be positive, got 0"
        );
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
