//! Kill-point matrix (ISSUE 10): abort at every registered store
//! failpoint and prove `store fsck --repair` + reopen always lands on
//! a state byte-identical to either *before* or *after* the
//! interrupted operation — never a third state.
//!
//! Mechanics: the parent test re-spawns this test binary filtered to
//! [`kill_point_child`], which drives the real CLI (`ingest` or
//! `store compact`) with `TALP_FAILPOINTS=<point>=crash` in its
//! environment.  The child aborts at the failpoint (exit status is the
//! proof the point fired); the parent then repairs the crashed store
//! and compares the full on-disk tree against snapshots taken before
//! the operation and after a clean run of it.
//!
//! The 17 store-side points are covered here (`serve::refresh` is
//! exercised by the degraded-mode serve test, which needs a live
//! monitor rather than a crash).

#![cfg(feature = "failpoints")]

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::Command;

use talp_pages::cli;
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::{copy_tree, TempDir};

/// Points an `ingest --input --store` pass consults, in consult
/// order: lock, shard append, manifest save, sidecar refresh, unlock.
const INGEST_POINTS: &[&str] = &[
    "store::lock::create",
    "store::append::write",
    "store::append::fsync",
    "store::append::dir_fsync",
    "store::manifest::write",
    "store::manifest::fsync",
    "store::manifest::rename",
    "store::manifest::dir_fsync",
    "store::index::write",
    "store::index::fsync",
    "store::index::rename",
    "store::index::dir_fsync",
    "store::lock::release",
];

/// Points specific to the `store compact` shard rewrite (its lock,
/// manifest and sidecar stages reuse the sites covered above).
const COMPACT_POINTS: &[&str] = &[
    "store::compact::write",
    "store::compact::fsync",
    "store::compact::rename",
    "store::compact::dir_fsync",
];

/// Hand-built run with exact numbers (no simulator noise), so
/// re-ingesting the same path with a different `elapsed` supersedes.
fn run(elapsed: f64, ts: i64, commit: &str, ranks: u32) -> RunData {
    let region = |name: &str, e: f64| RegionData {
        name: name.into(),
        elapsed_s: e,
        visits: 1,
        procs: (0..ranks)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: e,
                useful_s: e * 1.5,
                mpi_s: 0.05 * e,
                useful_instructions: 1_000_000,
                useful_cycles: 500_000,
                ..Default::default()
            })
            .collect(),
    };
    RunData {
        dlb_version: "test".into(),
        app: "crash-fixture".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks,
        threads: ranks,
        nodes: 1,
        regions: vec![
            region("Global", elapsed),
            region("solve", elapsed * 0.6),
        ],
        git: Some(GitMeta {
            commit: commit.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

/// One experiment `exp`, config `2x2`, three runs.  `elapsed_base`
/// varies the content so a second pass at the same paths supersedes.
fn build_tree(root: &Path, elapsed_base: f64) {
    for i in 0..3 {
        run(
            elapsed_base + i as f64,
            1000 + i as i64 * 100,
            &format!("c{i:03}"),
            2,
        )
        .write_file(&root.join(format!("exp/talp_2x2_run{i}.json")))
        .unwrap();
    }
}

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

/// Full byte-level tree snapshot: relative path -> file contents.
fn snapshot(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(
        root: &Path,
        dir: &Path,
        out: &mut BTreeMap<String, Vec<u8>>,
    ) {
        for entry in std::fs::read_dir(dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                walk(root, &path, out);
            } else {
                let rel = path
                    .strip_prefix(root)
                    .unwrap()
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy())
                    .collect::<Vec<_>>()
                    .join("/");
                out.insert(rel, std::fs::read(&path).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

/// Human-readable difference summary for assertion messages.
fn describe_diff(
    got: &BTreeMap<String, Vec<u8>>,
    want: &BTreeMap<String, Vec<u8>>,
) -> String {
    let mut parts = Vec::new();
    for k in want.keys() {
        match got.get(k) {
            None => parts.push(format!("missing {k}")),
            Some(v) if v != &want[k] => {
                parts.push(format!(
                    "differs {k} ({} vs {} bytes)",
                    v.len(),
                    want[k].len()
                ));
            }
            Some(_) => {}
        }
    }
    for k in got.keys() {
        if !want.contains_key(k) {
            parts.push(format!("extra {k}"));
        }
    }
    if parts.is_empty() { "identical".into() } else { parts.join(", ") }
}

/// The child half of the matrix: re-run under `--exact` with
/// `TALP_KILL_OP`/`TALP_KILL_STORE` (and `TALP_KILL_INPUT` for
/// ingest) plus a `TALP_FAILPOINTS=<point>=crash` spec.  Without the
/// env vars (a normal `cargo test` pass) it is a no-op.
#[test]
fn kill_point_child() {
    let Ok(op) = std::env::var("TALP_KILL_OP") else {
        return;
    };
    let store = std::env::var("TALP_KILL_STORE").unwrap();
    match op.as_str() {
        "ingest" => {
            let input = std::env::var("TALP_KILL_INPUT").unwrap();
            run_cli(&format!("ingest --input {input} --store {store}"))
                .unwrap();
        }
        "compact" => {
            run_cli(&format!(
                "store compact --store {store} --threshold 0"
            ))
            .unwrap();
        }
        other => panic!("unknown TALP_KILL_OP '{other}'"),
    }
}

/// Run `op` against a fresh copy of `base`, crashing at `point`; then
/// fsck-repair and assert the recovered tree is byte-identical to
/// `pre` or `post`, and that indexed and full-scan queries agree on
/// the recovered store.
fn kill_and_recover(
    td: &TempDir,
    op: &str,
    point: &str,
    base: &Path,
    input: Option<&Path>,
    pre: &BTreeMap<String, Vec<u8>>,
    post: &BTreeMap<String, Vec<u8>>,
) {
    let tag = point.replace("::", "-");
    let work = td.path().join(format!("work-{op}-{tag}"));
    copy_tree(base, &work).unwrap();

    let exe = std::env::current_exe().unwrap();
    let mut cmd = Command::new(&exe);
    cmd.args(["kill_point_child", "--exact", "--nocapture"])
        .env("TALP_KILL_OP", op)
        .env("TALP_KILL_STORE", &work)
        .env("TALP_FAILPOINTS", format!("{point}=crash"))
        .env("TALP_FAILPOINT_SEED", "42");
    if let Some(input) = input {
        cmd.env("TALP_KILL_INPUT", input);
    }
    let out = cmd.output().unwrap();
    assert!(
        !out.status.success(),
        "{op}/{point}: child exited cleanly — the failpoint never \
         fired\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );

    // Dry-run first: fsck must never mutate without --repair.
    let before_fsck = snapshot(&work);
    run_cli(&format!("store fsck --store {}", work.display())).unwrap();
    assert_eq!(
        snapshot(&work),
        before_fsck,
        "{op}/{point}: dry-run fsck mutated the store"
    );

    let code = run_cli(&format!(
        "store fsck --store {} --repair",
        work.display()
    ))
    .unwrap();
    assert_eq!(code, 0, "{op}/{point}: fsck --repair left errors");

    let got = snapshot(&work);
    assert!(
        got == *pre || got == *post,
        "{op}/{point}: recovered store is a third state\n  vs pre:  \
         {}\n  vs post: {}",
        describe_diff(&got, pre),
        describe_diff(&got, post)
    );

    // Acceptance: indexed selection over the recovered store matches
    // the sequential full scan byte for byte.
    let qi = td.path().join(format!("q-{op}-{tag}-indexed.jsonl"));
    let qs = td.path().join(format!("q-{op}-{tag}-scan.jsonl"));
    run_cli(&format!(
        "store query --store {} --output {}",
        work.display(),
        qi.display()
    ))
    .unwrap();
    run_cli(&format!(
        "store query --store {} --no-index --output {}",
        work.display(),
        qs.display()
    ))
    .unwrap();
    assert_eq!(
        std::fs::read(&qi).unwrap(),
        std::fs::read(&qs).unwrap(),
        "{op}/{point}: indexed query != full scan on recovered store"
    );
}

/// The matrix itself: every store-side registered point, under the
/// operation that consults it.
#[test]
fn kill_point_matrix_recovers_to_pre_or_post() {
    let td = TempDir::new("crash-matrix").unwrap();

    // Ingest fixture: a healthy store holding experiment `exp`, plus a
    // drop directory with one run in a NEW experiment/config so the
    // interrupted ingest creates a fresh shard (this is what makes
    // `store::append::dir_fsync` — parent fsync after file creation —
    // reachable).
    let tree = td.path().join("tree-v1");
    build_tree(&tree, 10.0);
    let ingest_base = td.path().join("base-ingest");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            tree.display(),
            ingest_base.display()
        ))
        .unwrap(),
        0
    );
    let drop_dir = td.path().join("drop");
    run(30.0, 5000, "d000", 4)
        .write_file(&drop_dir.join("late/talp_4x4_run0.json"))
        .unwrap();

    let ingest_pre = snapshot(&ingest_base);
    let ingest_post_dir = td.path().join("post-ingest");
    copy_tree(&ingest_base, &ingest_post_dir).unwrap();
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            drop_dir.display(),
            ingest_post_dir.display()
        ))
        .unwrap(),
        0
    );
    let ingest_post = snapshot(&ingest_post_dir);
    assert_ne!(ingest_pre, ingest_post, "drop ingest must change state");

    for point in INGEST_POINTS {
        kill_and_recover(
            &td,
            "ingest",
            point,
            &ingest_base,
            Some(&drop_dir),
            &ingest_pre,
            &ingest_post,
        );
    }

    // Compact fixture: re-ingest the same source paths with changed
    // content so every shard carries superseded (dead) bytes and
    // `--threshold 0` rewrites it.
    let compact_base = td.path().join("base-compact");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            tree.display(),
            compact_base.display()
        ))
        .unwrap(),
        0
    );
    let tree2 = td.path().join("tree-v2");
    build_tree(&tree2, 20.0);
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            tree2.display(),
            compact_base.display()
        ))
        .unwrap(),
        0
    );

    let compact_pre = snapshot(&compact_base);
    let compact_post_dir = td.path().join("post-compact");
    copy_tree(&compact_base, &compact_post_dir).unwrap();
    assert_eq!(
        run_cli(&format!(
            "store compact --store {} --threshold 0",
            compact_post_dir.display()
        ))
        .unwrap(),
        0
    );
    let compact_post = snapshot(&compact_post_dir);
    assert_ne!(
        compact_pre, compact_post,
        "compact must rewrite the superseded shard"
    );

    for point in COMPACT_POINTS {
        kill_and_recover(
            &td,
            "compact",
            point,
            &compact_base,
            None,
            &compact_pre,
            &compact_post,
        );
    }
}
