//! Cross-module integration tests: the full run -> folder -> report
//! pipeline, the CLI surface, the CI cycle with Fig. 7 detection, and
//! the AOT runtime path (gated on `make artifacts`).

use talp_pages::apps::{run_with_talp, CodeVersion, Genex, TeaLeaf};
use talp_pages::ci::{CiEngine, MatrixSpec, PipelineOptions, Repo};
use talp_pages::cli;
use talp_pages::pages::{scan, timeseries};
use talp_pages::pop;
use talp_pages::session::{self, AnalyzeOptions, Session};
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::tools::{self, ToolKind};
use talp_pages::util::fs::TempDir;

fn mn5() -> MachineSpec {
    MachineSpec::marenostrum5()
}

#[test]
fn full_standalone_workflow() {
    // run 3 configs -> Fig. 2 folder -> report with table + badges.
    let td = TempDir::new("itg-standalone").unwrap();
    let folder = td.path().join("talp_folder");
    let mut app = TeaLeaf::with_grid(1000, 1000);
    app.timesteps = 1;
    app.cg_iters = 8;
    app.write_output = false;
    for cfg in [
        ResourceConfig::new(2, 8),
        ResourceConfig::new(4, 8),
        ResourceConfig::new(8, 8),
    ] {
        let (d, _) = run_with_talp(&app, &mn5(), &cfg, 5, 1_700_000_000);
        d.write_file(
            &folder.join(format!("grid/strong/talp_{}.json", cfg.label())),
        )
        .unwrap();
    }
    let out = td.path().join("report");
    let summary = Session::new(&folder)
        .scan()
        .unwrap()
        .analyze(&AnalyzeOptions::default())
        .emit(&mut session::default_emitters(&out))
        .unwrap();
    assert_eq!(summary.experiments, 1);
    assert_eq!(summary.badges_written, 3);
    let html =
        std::fs::read_to_string(out.join("grid_strong.html")).unwrap();
    assert!(html.contains("strong scaling"));
    assert!(html.contains("2x8"));
    assert!(html.contains("8x8"));
    // Table columns ordered by resources with reference first.
    let scanres = scan(&folder).unwrap();
    let t = pop::build("Global", &scanres.experiments[0].latest_per_config())
        .unwrap();
    assert_eq!(t.columns, vec!["2x8", "4x8", "8x8"]);
    // Reference column is exactly 1 on scalability rows.
    assert!((t.cell("IPC scaling", 0).unwrap() - 1.0).abs() < 1e-9);
}

#[test]
fn ci_cycle_detects_fig7_fix() {
    let td = TempDir::new("itg-ci").unwrap();
    let repo = Repo::genex_history(6, 3, 17, 1_690_000_000);
    let jobs = MatrixSpec {
        case: "salpha".into(),
        resolutions: vec![1],
        configurations: vec![("1Nx2MPI".into(), 2, 8)],
        machine_tags: vec!["mn5".into()],
    }
    .expand();
    let opts = PipelineOptions {
        analyze: AnalyzeOptions {
            regions: vec!["initialize".into(), "timestep".into()],
            region_for_badge: Some("timestep".into()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut engine = CiEngine::new(td.path()).unwrap();
    for c in &repo.commits {
        engine.run_pipeline(c, &jobs, &opts).unwrap();
    }
    let work = talp_pages::util::fs::subdirs(&td.path().join("work"));
    let talp_dir = work.last().unwrap().join("talp");
    let scanres = scan(&talp_dir).unwrap();
    let exp = &scanres.experiments[0];
    let hist = exp.history_for_config("2x8");
    assert_eq!(hist.len(), 6);
    let ts = timeseries::build("2x8", &hist, &[]);
    let el = ts.metric("initialize", "elapsed");
    assert!(el[3].1 < 0.7 * el[2].1, "fix not visible: {el:?}");
    let ser = ts.metric("initialize", "omp_serialization_efficiency");
    assert!(ser[3].1 > ser[2].1 + 0.15);
    // The published pages contain the fix commit's sha.
    let pages_html: Vec<_> =
        talp_pages::util::fs::files_with_ext(engine.pages_dir(), "html");
    let body = pages_html
        .iter()
        .map(|p| std::fs::read_to_string(p).unwrap())
        .collect::<String>();
    assert!(body.contains(repo.commits[3].short()));
}

#[test]
fn cli_end_to_end_surface() {
    let td = TempDir::new("itg-cli").unwrap();
    let run_cli = |line: &str| {
        cli::main_with_args(
            &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
        )
    };
    let json = td.path().join("talp/exp/a.json");
    assert_eq!(
        run_cli(&format!(
            "run --app tealeaf --grid 600 --iters 6 --machine raven \
             --config 2x8 --output {}",
            json.display()
        ))
        .unwrap(),
        0
    );
    let ci_sim_out = td.path().join("cisim");
    assert_eq!(
        run_cli(&format!(
            "ci-sim --output {} --commits 3 --fix-at 1",
            ci_sim_out.display()
        ))
        .unwrap(),
        0
    );
    // The report publishes under public/talp -> pages/talp/.
    assert!(ci_sim_out.join("pages/talp/index.html").exists());
}

#[test]
fn tool_chains_consistent_with_direct_talp_run() {
    // TALP chain output must equal a direct run_with_talp (same seed).
    let td = TempDir::new("itg-tools").unwrap();
    let mut app = TeaLeaf::with_grid(800, 800);
    app.timesteps = 1;
    app.cg_iters = 6;
    app.write_output = false;
    let cfg = ResourceConfig::new(2, 8);
    let run = tools::instrument(
        ToolKind::Talp,
        &app,
        &mn5(),
        &cfg,
        123,
        42,
        td.path(),
    )
    .unwrap();
    let from_chain = talp_pages::talp::RunData::read_file(
        &run.output_dir.join("talp.json"),
    )
    .unwrap();
    let (direct, _) = run_with_talp(&app, &mn5(), &cfg, 123, 42);
    let a = pop::compute(from_chain.region("Global").unwrap(), 8);
    let b = pop::compute(direct.region("Global").unwrap(), 8);
    // Identical up to the JSON round-trip's integer-ns quantization.
    assert!((a.parallel_efficiency - b.parallel_efficiency).abs() < 1e-5);
    assert_eq!(
        a.total_useful_instructions,
        b.total_useful_instructions
    );
}

#[test]
fn genex_step_artifact_runs_when_built() {
    // Gated on `make artifacts`.
    let Some(reg) = talp_pages::runtime::Registry::open_default() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let meta = reg.find("genex_step", 128, 128).expect("genex artifact");
    let mut rt = talp_pages::runtime::XlaRuntime::cpu().unwrap();
    rt.load(meta).unwrap();
    let (h, w) = (128usize, 128usize);
    let u = talp_pages::runtime::native::Grid::initial_condition(h, w);
    let c = talp_pages::runtime::native::build_coefficients(h, w, 0.5, 1.0);
    let out = rt
        .execute(
            &meta.name,
            &[
                (&u.data, &[h as i64, w as i64]),
                (&c.kx.data, &[h as i64, (w + 1) as i64]),
                (&c.ky.data, &[h as i64, w as i64]),
                (&c.d.data, &[h as i64, w as i64]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].dims, vec![h, w]);
    assert!(out[0].data.iter().all(|v| v.is_finite()));
    // Bounded evolution (the tanh-stabilized update).
    let norm0: f64 =
        u.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
    let norm1: f64 =
        out[0].data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
    assert!(norm1 < 4.0 * norm0);
}

#[test]
fn buggy_vs_fixed_report_difference_survives_html() {
    // The Fig. 7 explanation must be visible in the *rendered* numbers.
    let td = TempDir::new("itg-html").unwrap();
    let folder = td.path().join("talp");
    let machine = mn5();
    let cfg = ResourceConfig::new(2, 14);
    for (i, version) in
        [CodeVersion::buggy(), CodeVersion::fixed()].iter().enumerate()
    {
        let mut app = Genex::salpha(2, *version);
        app.timesteps = 2;
        let (mut d, _) =
            run_with_talp(&app, &machine, &cfg, 3, 1_700_000_000);
        d.git = Some(talp_pages::talp::GitMeta {
            commit: format!("c{i}{}", "0".repeat(39)),
            branch: "main".into(),
            commit_timestamp: 1_700_000_000 + i as i64 * 86400,
            message: String::new(),
        });
        d.write_file(&folder.join(format!("exp/run_{i}.json"))).unwrap();
    }
    let out = td.path().join("public");
    Session::new(&folder)
        .scan()
        .unwrap()
        .analyze(&AnalyzeOptions {
            regions: vec!["initialize".into()],
            region_for_badge: Some("initialize".into()),
            ..Default::default()
        })
        .emit(&mut session::default_emitters(&out))
        .unwrap();
    let html = std::fs::read_to_string(out.join("exp.html")).unwrap();
    assert!(html.contains("OpenMP Serialization efficiency"));
    assert!(html.contains("Time evolution"));
    assert!(html.contains("polyline"));
}
