//! Acceptance tests for the machine-readable `report.json` contract
//! (ISSUE 3):
//!
//! * golden file: the emitted document matches
//!   `tests/golden/report.json` byte-for-byte (regenerate with
//!   `UPDATE_GOLDEN=1 cargo test --test report_json`; a missing golden
//!   bootstraps itself on first run so fresh checkouts can seed it);
//! * byte-identical across `jobs = 1` / `jobs = 4` and cold/warm
//!   metrics cache;
//! * a warm-cache JsonReport-only emit parses zero artifacts and still
//!   reports the scan's cache counters correctly (counting lives in
//!   the scan/analyze stages, not in any emitter);
//! * schema_version round-trip and rejection.

use std::path::{Path, PathBuf};

use talp_pages::session::{
    AnalyzeOptions, EmitSummary, Emitter, JsonReport, ReportDocument,
    Session, SCHEMA_VERSION,
};
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;

/// Hand-built run with exact decimal inputs — no simulator, so the
/// document is reproducible across machines and runs.
fn run(
    ranks: u32,
    useful_per_proc: f64,
    elapsed: f64,
    ts: i64,
    commit: &str,
) -> RunData {
    let region = |name: &str, e: f64, scale: f64| RegionData {
        name: name.into(),
        elapsed_s: e,
        visits: 1,
        procs: (0..ranks)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: e,
                useful_s: useful_per_proc * scale,
                mpi_s: 0.05 * e,
                mpi_worker_idle_s: 0.05 * e,
                omp_serialization_s: 0.01 * e,
                omp_scheduling_s: 0.01 * e,
                omp_barrier_s: 0.02 * e,
                useful_instructions: 1_000_000 / ranks as u64,
                useful_cycles: 500_000 / ranks as u64,
            })
            .collect(),
    };
    RunData {
        dlb_version: "test".into(),
        app: "golden".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks,
        threads: 2,
        nodes: 1,
        regions: vec![
            region("Global", elapsed, 1.0),
            region("solve", elapsed * 0.6, 0.55),
        ],
        git: Some(GitMeta {
            commit: commit.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

/// Fixture: one experiment, two configs; the 2x2 history carries a
/// 16 -> 10 elapsed drop so a detection appears in the document.
fn build_fixture(root: &Path) {
    run(2, 24.0, 16.0, 1000, "slowslow1")
        .write_file(&root.join("exp/talp_2x2_run0.json"))
        .unwrap();
    run(2, 15.0, 10.0, 2000, "fastfast2")
        .write_file(&root.join("exp/talp_2x2_run1.json"))
        .unwrap();
    run(4, 15.0, 10.0, 1000, "slowslow1")
        .write_file(&root.join("exp/talp_4x2_run0.json"))
        .unwrap();
    run(4, 15.0, 10.0, 2000, "fastfast2")
        .write_file(&root.join("exp/talp_4x2_run1.json"))
        .unwrap();
}

/// Emit only `report.json` and return (document text, summary).
fn emit_json(
    input: &Path,
    out: &Path,
    jobs: usize,
    cache: Option<PathBuf>,
) -> (String, EmitSummary) {
    let mut emitters: Vec<Box<dyn Emitter>> =
        vec![Box::new(JsonReport::new(out))];
    let summary = Session::new(input)
        .jobs(jobs)
        .cache_opt(cache)
        .scan()
        .unwrap()
        .analyze(&AnalyzeOptions::default())
        .emit(&mut emitters)
        .unwrap();
    let text =
        std::fs::read_to_string(out.join("report.json")).unwrap();
    (text, summary)
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/report.json")
}

#[test]
fn report_json_matches_golden_and_is_deterministic() {
    let input = TempDir::new("rj-in").unwrap();
    build_fixture(input.path());

    // ---- byte-identical across jobs values (cold cache) ----
    let out1 = TempDir::new("rj-out1").unwrap();
    let out4 = TempDir::new("rj-out4").unwrap();
    let (t1, s1) = emit_json(input.path(), out1.path(), 1, None);
    let (t4, s4) = emit_json(input.path(), out4.path(), 4, None);
    assert_eq!(s1.cache_misses, 4);
    assert_eq!(s4.cache_misses, 4);
    assert_eq!(t1, t4, "report.json differs between jobs 1 and jobs 4");

    // ---- byte-identical across cache temperature ----
    // (cache outside the scanned root, like the CLI's out-dir default)
    let cache_dir = TempDir::new("rj-cache").unwrap();
    let cache = cache_dir.path().join(".talp-cache.json");
    let outc = TempDir::new("rj-outc").unwrap();
    let (t_cold, s_cold) =
        emit_json(input.path(), outc.path(), 2, Some(cache.clone()));
    assert_eq!(s_cold.cache_misses, 4, "first cached run is cold");
    let (t_warm, s_warm) =
        emit_json(input.path(), outc.path(), 2, Some(cache));
    assert_eq!(s_warm.cache_hits, 4, "second run must be fully warm");
    assert_eq!(s_warm.cache_misses, 0);
    assert_eq!(t_cold, t_warm, "report.json differs cold vs warm");
    assert_eq!(t1, t_cold, "cached and uncached documents differ");

    // ---- the golden file ----
    let golden = golden_path();
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !golden.exists() {
        // Bootstrap/regenerate: commit the result so drift in the
        // schema shows up as a reviewable diff.
        std::fs::write(&golden, &t1).unwrap();
        return;
    }
    let want = std::fs::read_to_string(&golden).unwrap();
    assert_eq!(
        t1, want,
        "report.json drift vs tests/golden/report.json; if intentional, \
         regenerate with UPDATE_GOLDEN=1 cargo test --test report_json"
    );
}

#[test]
fn json_only_emit_keeps_scan_counters_correct() {
    // Satellite fix: cache hit/miss counters belong to the scan, so
    // they must stay correct when the HTML emitter is disabled.
    let input = TempDir::new("rj-counters-in").unwrap();
    build_fixture(input.path());
    let out = TempDir::new("rj-counters-out").unwrap();
    let cache = out.path().join("cache.json");

    let (_, cold) =
        emit_json(input.path(), out.path(), 0, Some(cache.clone()));
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.cache_misses, 4);
    assert_eq!(cold.pages_written, 0, "no HTML emitter ran");
    assert_eq!(cold.badges_written, 0, "no badge emitter ran");
    assert_eq!(cold.files_written, 1, "just report.json");

    let (_, warm) = emit_json(input.path(), out.path(), 0, Some(cache));
    assert_eq!(warm.cache_hits, 4, "warm JSON-only emit must hit");
    assert_eq!(warm.cache_misses, 0, "warm JSON-only emit parses nothing");
    assert_eq!(warm.experiments, 1);
    assert_eq!(warm.emitters.len(), 1);
    assert_eq!(warm.emitters[0].name, "json-report");
}

#[test]
fn schema_version_round_trips_and_rejects_unknown() {
    let input = TempDir::new("rj-schema-in").unwrap();
    build_fixture(input.path());
    let out = TempDir::new("rj-schema-out").unwrap();
    let (text, _) = emit_json(input.path(), out.path(), 0, None);

    // Round trip: parse validates the version and reconstructs the
    // histories with full POP factors.
    let doc = ReportDocument::parse(&text).unwrap();
    assert_eq!(doc.schema_version, SCHEMA_VERSION);
    assert_eq!(doc.experiments.len(), 1);
    let exp = &doc.experiments[0];
    assert_eq!(exp.id, "exp");
    assert_eq!(exp.configs.len(), 2);
    let (cfg, history) = &exp.configs[0];
    assert_eq!(cfg, "2x2");
    assert_eq!(history.len(), 2);
    assert_eq!(history[0].source, "exp/talp_2x2_run0.json");
    assert!(history[0].region("Global").unwrap().metrics.elapsed_s > 0.0);
    // The injected 16 -> 10 improvement is in the detections.
    assert!(exp
        .detections
        .iter()
        .any(|d| d.str_or("kind", "") == "improvement"
            && d.str_or("config", "") == "2x2"));

    // Rejection: a bumped version must refuse to parse.
    let bumped = text.replace(
        "\"schema_version\": 2",
        "\"schema_version\": 3",
    );
    assert_ne!(text, bumped);
    let err = ReportDocument::parse(&bumped).unwrap_err().to_string();
    assert!(err.contains("unsupported schema_version"), "{err}");
}
