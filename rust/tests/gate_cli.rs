//! Acceptance tests for the regression-gate subsystem (ISSUE 2):
//!
//! * `talp gate` exits non-zero on an injected regression in a
//!   synthetic history and zero on a clean one;
//! * all three verdict artifacts (`gate.json`, `gate.md`, `gate.xml`)
//!   are byte-identical across `--jobs` values and cache temperature;
//! * `ci-report --gate` gates inline on the report's own (warm) scan.

use std::path::Path;

use talp_pages::cli;
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;

/// Hand-built run with an exact elapsed time (no simulator noise).
fn run(elapsed: f64, ts: i64, commit: &str) -> RunData {
    let region = |name: &str, e: f64| RegionData {
        name: name.into(),
        elapsed_s: e,
        visits: 1,
        procs: (0..2)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: e,
                useful_s: e * 1.5,
                mpi_s: 0.05 * e,
                useful_instructions: 1_000_000,
                useful_cycles: 500_000,
                ..Default::default()
            })
            .collect(),
    };
    RunData {
        dlb_version: "test".into(),
        app: "gate-fixture".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks: 2,
        threads: 2,
        nodes: 1,
        regions: vec![region("Global", elapsed), region("solve", elapsed * 0.6)],
        git: Some(GitMeta {
            commit: commit.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

/// One experiment, one config, elapsed times as given (oldest first).
fn build_history(root: &Path, elapsed: &[f64]) {
    for (i, e) in elapsed.iter().enumerate() {
        run(*e, 1000 + i as i64 * 100, &format!("commit{i:02}x"))
            .write_file(&root.join(format!("exp/talp_2x2_run{i}.json")))
            .unwrap();
    }
}

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

fn read(dir: &Path, name: &str) -> String {
    std::fs::read_to_string(dir.join(name))
        .unwrap_or_else(|e| panic!("{name}: {e}"))
}

#[test]
fn gate_exits_nonzero_on_regression_zero_on_clean() {
    let td = TempDir::new("gate-accept").unwrap();

    let clean = td.path().join("clean");
    build_history(&clean, &[10.0, 10.0, 10.0, 10.0]);
    let clean_out = td.path().join("clean-gate");
    let code = run_cli(&format!(
        "gate --input {} --output {}",
        clean.display(),
        clean_out.display()
    ))
    .unwrap();
    assert_eq!(code, 0, "clean history must pass");
    assert!(read(&clean_out, "gate.json").contains("\"status\": \"pass\""));

    let bad = td.path().join("regressed");
    build_history(&bad, &[10.0, 10.0, 10.0, 16.0]);
    let bad_out = td.path().join("bad-gate");
    let code = run_cli(&format!(
        "gate --input {} --output {}",
        bad.display(),
        bad_out.display()
    ))
    .unwrap();
    assert_eq!(code, 1, "injected regression must fail the gate");
    let json = read(&bad_out, "gate.json");
    assert!(json.contains("\"status\": \"fail\""));
    assert!(json.contains("\"commit\": \"commit03x\""));
    let md = read(&bad_out, "gate.md");
    assert!(md.contains("## TALP performance gate: **FAIL**"));
    assert!(md.contains("+60.0%"));
    let xml = read(&bad_out, "gate.xml");
    assert!(xml.contains("<failure message="));
    assert!(xml.contains("testsuite name=\"exp\""));
}

#[test]
fn verdicts_byte_identical_across_jobs_and_cache_temperature() {
    let td = TempDir::new("gate-determinism").unwrap();
    let input = td.path().join("talp");
    build_history(&input, &[10.0, 10.0, 10.0, 16.0]);

    let out1 = td.path().join("gate-j1");
    let out4 = td.path().join("gate-j4");
    let cache = td.path().join("cache.json");
    let code1 = run_cli(&format!(
        "gate --input {} --output {} --jobs 1",
        input.display(),
        out1.display()
    ))
    .unwrap();
    let code4 = run_cli(&format!(
        "gate --input {} --output {} --jobs 4 --cache {}",
        input.display(),
        out4.display(),
        cache.display()
    ))
    .unwrap();
    assert_eq!(code1, 1);
    assert_eq!(code4, 1);
    for f in ["gate.json", "gate.md", "gate.xml"] {
        assert_eq!(
            read(&out1, f),
            read(&out4, f),
            "{f} differs between --jobs 1 and --jobs 4"
        );
    }

    // Warm rerun through the cache: byte-identical again.
    let out_warm = td.path().join("gate-warm");
    run_cli(&format!(
        "gate --input {} --output {} --jobs 2 --cache {}",
        input.display(),
        out_warm.display(),
        cache.display()
    ))
    .unwrap();
    for f in ["gate.json", "gate.md", "gate.xml"] {
        assert_eq!(
            read(&out1, f),
            read(&out_warm, f),
            "{f} differs between cold and warm cache"
        );
    }
}

#[test]
fn ci_report_gates_inline() {
    let td = TempDir::new("gate-inline").unwrap();
    let input = td.path().join("talp");
    build_history(&input, &[10.0, 10.0, 10.0, 16.0]);
    let pol = td.path().join("policy.json");
    std::fs::write(
        &pol,
        r#"{"version":1,"defaults":{"max_elapsed_increase":0.2}}"#,
    )
    .unwrap();
    let site = td.path().join("public");
    let code = run_cli(&format!(
        "ci-report --input {} --output {} --gate {}",
        input.display(),
        site.display(),
        pol.display()
    ))
    .unwrap();
    assert_eq!(code, 1, "+60% elapsed must fail a 20% policy");
    // The verdict triple and the badge land next to the pages.
    for f in ["gate.json", "gate.md", "gate.xml", "badges/gate.svg",
              "index.html"] {
        assert!(site.join(f).exists(), "{f} missing");
    }
    assert!(read(&site, "index.html").contains("Performance gate: FAIL"));
    assert!(read(&site, "badges/gate.svg").contains("failing"));

    // An allowlist covering the offending commit turns it green.
    std::fs::write(
        &pol,
        r#"{"version":1,
            "defaults":{"max_elapsed_increase":0.2},
            "allow":[{"region":"*","commit":"commit03x",
                      "reason":"accepted: accuracy fix"}]}"#,
    )
    .unwrap();
    let site2 = td.path().join("public2");
    let code = run_cli(&format!(
        "ci-report --input {} --output {} --gate {}",
        input.display(),
        site2.display(),
        pol.display()
    ))
    .unwrap();
    assert_eq!(code, 0, "allowlisted regression must not fail");
    let json = read(&site2, "gate.json");
    assert!(json.contains("\"outcome\": \"allowed\""));
    assert!(json.contains("accepted: accuracy fix"));
}
