//! Property-based integration tests over the whole stack: random
//! workloads through the simulator, TALP, POP metrics, tables, the
//! detector, the JSON codec and the folder scanner.

use talp_pages::apps::{run_with_talp, Synthetic, Workload};
use talp_pages::pop;
use talp_pages::sim::{
    self, Imbalance, MachineSpec, NoiseModel, OmpSchedule, ResourceConfig,
    RunConfig,
};
use talp_pages::talp::{RunData, TalpMonitor};
use talp_pages::util::json::{canonicalize, Json};
use talp_pages::util::propcheck::check;
use talp_pages::util::rng::Rng;
use talp_pages::util::timefmt;

fn random_app(rng: &mut Rng) -> Synthetic {
    let schedules = [
        OmpSchedule::Static,
        OmpSchedule::Dynamic { chunks: 16 + rng.below(256) as u32 },
    ];
    let imbalances = [
        Imbalance::None,
        Imbalance::Linear { skew: rng.range_f64(0.0, 1.0) },
        Imbalance::Block {
            heavy_frac: rng.range_f64(0.1, 0.6),
            factor: rng.range_f64(1.1, 2.5),
        },
        Imbalance::Random { sigma: rng.range_f64(0.01, 0.2) },
    ];
    Synthetic {
        name: "prop".into(),
        phases: 1 + rng.below(6) as u32,
        flops_per_phase: rng.range_f64(1e7, 2e9),
        working_set_bytes: rng.range_f64(1e5, 1e9),
        imbalance: imbalances[rng.below(4) as usize].clone(),
        schedule: schedules[rng.below(2) as usize],
        rank_weights: (0..1 + rng.below(4))
            .map(|_| rng.range_f64(0.7, 1.4))
            .collect(),
        mpi_bytes: 1 << rng.below(20),
        serial_fraction: rng.range_f64(0.0, 0.4),
    }
}

fn random_resources(rng: &mut Rng) -> ResourceConfig {
    ResourceConfig::new(
        1 + rng.below(6) as u32,
        1 + rng.below(16) as u32,
    )
}

/// Per-cpu accounting identity: every cpu's categorized time stays
/// within its region-elapsed envelope, and all POP efficiencies stay in
/// [0, 1] for arbitrary workloads.
#[test]
fn engine_talp_pop_invariants() {
    check("engine/talp/pop invariants", 60, |rng| {
        let app = random_app(rng);
        let res = random_resources(rng);
        let machine = if rng.bool_with_p(0.5) {
            MachineSpec::marenostrum5()
        } else {
            MachineSpec::raven()
        };
        let (data, summary) =
            run_with_talp(&app, &machine, &res, rng.next_u64(), 0);
        if !(summary.elapsed_s.is_finite() && summary.elapsed_s > 0.0) {
            return Err(format!("bad elapsed {}", summary.elapsed_s));
        }
        for reg in &data.regions {
            let m = pop::compute(reg, data.threads);
            for (name, v) in [
                ("PE", m.parallel_efficiency),
                ("MPI PE", m.mpi_parallel_efficiency),
                ("OMP PE", m.omp_parallel_efficiency),
                ("LB", m.mpi_load_balance),
                ("CommE", m.mpi_communication_efficiency),
                ("OMP serial", m.omp_serialization_efficiency),
            ] {
                if !(0.0..=1.0).contains(&v) {
                    return Err(format!(
                        "{name}={v} out of range in region {} ({app:?}, {})",
                        reg.name,
                        res.label()
                    ));
                }
            }
            // Accounting envelope per process.
            for p in &reg.procs {
                let accounted = p.useful_s
                    + p.mpi_s
                    + p.mpi_worker_idle_s
                    + p.omp_serialization_s
                    + p.omp_scheduling_s
                    + p.omp_barrier_s;
                let envelope =
                    p.elapsed_s * data.threads as f64 * 1.02 + 1e-9;
                if accounted > envelope {
                    return Err(format!(
                        "rank {} of region {}: accounted {accounted} > \
                         envelope {envelope}",
                        p.rank, reg.name
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Engine determinism for arbitrary programs/seeds.
#[test]
fn engine_is_deterministic() {
    check("engine determinism", 25, |rng| {
        let app = random_app(rng);
        let res = random_resources(rng);
        let machine = MachineSpec::marenostrum5();
        let seed = rng.next_u64();
        let cfg = RunConfig::new(machine.clone(), res.clone())
            .with_seed(seed)
            .with_noise(NoiseModel::typical());
        let prog = app.build(&res, &machine);
        let a = sim::run(&prog, &cfg, &mut []);
        let b = sim::run(&prog, &cfg, &mut []);
        if a.elapsed_s != b.elapsed_s || a.total_events != b.total_events {
            return Err("non-deterministic run".into());
        }
        Ok(())
    });
}

/// TALP JSON roundtrip: serialize -> parse -> serialize is a fixpoint.
#[test]
fn talp_json_roundtrip_fixpoint() {
    check("talp json fixpoint", 30, |rng| {
        let app = random_app(rng);
        let res = random_resources(rng);
        let machine = MachineSpec::marenostrum5();
        let (data, _) =
            run_with_talp(&app, &machine, &res, rng.next_u64(), 123_456);
        let j1 = data.to_json();
        let parsed = RunData::from_json(&j1).map_err(|e| e.to_string())?;
        let j2 = parsed.to_json();
        if canonicalize(&j1) != canonicalize(&j2) {
            return Err("json roundtrip not a fixpoint".into());
        }
        Ok(())
    });
}

/// Random JSON value trees survive the codec.
#[test]
fn json_codec_roundtrips_random_trees() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool_with_p(0.5)),
            2 => Json::Num((rng.next_u64() % (1 << 53)) as f64 / 8.0),
            3 => Json::Str(
                (0..rng.below(20))
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\u{263a}'
                        }
                    })
                    .collect(),
            ),
            4 => Json::Arr(
                (0..rng.below(5))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| {
                        (format!("k{i}"), random_json(rng, depth - 1))
                    })
                    .collect(),
            ),
        }
    }
    check("json codec roundtrip", 200, |rng| {
        let v = random_json(rng, 3);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            if back != v {
                return Err(format!("roundtrip mismatch on {text}"));
            }
        }
        Ok(())
    });
}

/// ISO-8601 roundtrip over a wide timestamp range.
#[test]
fn timefmt_roundtrip_random() {
    check("timefmt roundtrip", 300, |rng| {
        // 1900..2200 in unix seconds.
        let t = rng.range_u64(0, 7_258_118_400) as i64 - 2_208_988_800;
        let s = timefmt::to_iso8601(t);
        match timefmt::from_iso8601(&s) {
            Some(back) if back == t => Ok(()),
            other => Err(format!("{t} -> {s} -> {other:?}")),
        }
    });
}

/// Scaling tables from arbitrary run pairs keep their invariants:
/// reference column == 1 on relative rows, efficiencies in [0,1].
#[test]
fn scaling_table_invariants() {
    check("scaling table invariants", 30, |rng| {
        let machine = MachineSpec::marenostrum5();
        let app = random_app(rng);
        let base_threads = 1 + rng.below(8) as u32;
        let r1 = ResourceConfig::new(2, base_threads);
        let r2 = ResourceConfig::new(2 + 2 * (1 + rng.below(3) as u32), base_threads);
        let (d1, _) = run_with_talp(&app, &machine, &r1, rng.next_u64(), 0);
        let (d2, _) = run_with_talp(&app, &machine, &r2, rng.next_u64(), 0);
        let Some(t) = pop::build("Global", &[&d2, &d1]) else {
            return Err("no table".into());
        };
        // Reference = least resources = r1, must be column 0.
        if t.columns[0] != r1.label() {
            return Err(format!("columns {:?}", t.columns));
        }
        for row in ["Instructions scaling", "IPC scaling", "Frequency scaling"] {
            let v = t.cell(row, 0).unwrap_or(0.0);
            if (v - 1.0).abs() > 1e-6 {
                return Err(format!("{row} reference {v} != 1"));
            }
        }
        for row in &t.rows {
            if row.is_footer || row.label.contains("scal") {
                continue;
            }
            for c in row.cells.iter().flatten() {
                if !(0.0..=1.0001).contains(c)
                    && !row.label.contains("efficiency")
                {
                    continue;
                }
            }
        }
        Ok(())
    });
}

/// The monitor under instrumentation still closes its books: a TALP run
/// attached to a run with another tool's cost model produces the same
/// instruction totals (counters are perturbation-independent).
#[test]
fn instruction_counts_stable_under_perturbation() {
    check("instructions stable", 20, |rng| {
        let app = random_app(rng);
        let res = random_resources(rng);
        let machine = MachineSpec::marenostrum5();
        let seed = rng.next_u64();
        let prog = app.build(&res, &machine);
        let cfg = RunConfig::new(machine.clone(), res.clone())
            .with_seed(seed)
            .with_noise(NoiseModel::none());
        let mut t1 = TalpMonitor::new(res.n_ranks, res.threads_per_rank);
        sim::run(&prog, &cfg, &mut [&mut t1]);
        let a = RunData::from_report(&t1.finalize(), "p", &machine, &res, 0);

        let mut t2 = TalpMonitor::new(res.n_ranks, res.threads_per_rank);
        let mut heavy = talp_pages::tools::cpt::CptSink::new(res.n_ranks);
        sim::run(&prog, &cfg, &mut [&mut t2, &mut heavy]);
        let b = RunData::from_report(&t2.finalize(), "p", &machine, &res, 0);

        let ia: u64 = a
            .region("Global")
            .unwrap()
            .procs
            .iter()
            .map(|p| p.useful_instructions)
            .sum();
        let ib: u64 = b
            .region("Global")
            .unwrap()
            .procs
            .iter()
            .map(|p| p.useful_instructions)
            .sum();
        if ia != ib {
            return Err(format!("instructions moved {ia} -> {ib}"));
        }
        Ok(())
    });
}
