//! Acceptance tests for `talp-pages check` (ISSUE 6):
//!
//! * the corruption ladder: one seeded mutation per diagnostic code,
//!   each asserting its documented `TP0xx` code in *both* the text and
//!   the SARIF rendering;
//! * byte-determinism across `--jobs` values;
//! * a SARIF golden (fixed synthetic paths, `UPDATE_GOLDEN=1` to
//!   regenerate);
//! * properties: the analyzer never panics on corrupted bytes and
//!   every reported span stays within its file.

use std::path::{Path, PathBuf};

use talp_pages::check::{
    run_check, sarif, CheckOptions, CheckReport, Diagnostic, Severity,
    Span,
};
use talp_pages::cli;
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;
use talp_pages::util::propcheck;

/// Hand-built run with exact numbers (no simulator noise).
fn run(elapsed: f64, ts: i64, commit: &str) -> RunData {
    let region = |name: &str, e: f64| RegionData {
        name: name.into(),
        elapsed_s: e,
        visits: 1,
        procs: (0..2)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: e,
                useful_s: e * 1.5,
                mpi_s: 0.05 * e,
                useful_instructions: 1_000_000,
                useful_cycles: 500_000,
                ..Default::default()
            })
            .collect(),
    };
    RunData {
        dlb_version: "test".into(),
        app: "check-fixture".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks: 2,
        threads: 2,
        nodes: 1,
        regions: vec![
            region("Global", elapsed),
            region("solve", elapsed * 0.6),
        ],
        git: Some(GitMeta {
            commit: commit.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

/// One experiment `exp`, one config `2x2`, distinct timestamps.
fn build_tree(root: &Path) {
    for i in 0..3 {
        run(10.0 + i as f64, 1000 + i as i64 * 100, &format!("c{i:03}"))
            .write_file(&root.join(format!("exp/talp_2x2_run{i}.json")))
            .unwrap();
    }
}

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

fn codes(rep: &CheckReport) -> Vec<&'static str> {
    rep.diagnostics.iter().map(|d| d.code).collect()
}

/// Run the check and assert `code` shows up in the structured report,
/// the text rendering and the SARIF rendering alike.
fn assert_code(opts: &CheckOptions, code: &str, what: &str) {
    let rep = run_check(opts).unwrap_or_else(|e| panic!("{what}: {e}"));
    assert!(
        rep.diagnostics.iter().any(|d| d.code == code),
        "{what}: expected {code}, got {:?}",
        rep.diagnostics
    );
    let text = rep.render_text();
    assert!(text.contains(&format!("[{code}]")), "{what} text:\n{text}");
    let sarif = sarif::render(&rep);
    assert!(
        sarif.contains(&format!("\"ruleId\": \"{code}\"")),
        "{what} sarif:\n{sarif}"
    );
}

fn input_opts(root: &Path) -> CheckOptions {
    CheckOptions { input: Some(root.to_path_buf()), ..Default::default() }
}

fn store_opts(store: &Path) -> CheckOptions {
    CheckOptions { store: Some(store.to_path_buf()), ..Default::default() }
}

#[test]
fn clean_fixture_is_clean_in_every_surface() {
    let td = TempDir::new("check-clean").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    let store = td.path().join("store");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            talp.display(),
            store.display()
        ))
        .unwrap(),
        0
    );
    let policy = td.path().join("policy.json");
    std::fs::write(
        &policy,
        r#"{"version":1,"rules":[{"region":"solve","max_elapsed_increase":0.5}]}"#,
    )
    .unwrap();
    let rep = run_check(&CheckOptions {
        store: Some(store),
        policy: Some(policy),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(codes(&rep), Vec::<&str>::new(), "{:?}", rep.diagnostics);
    assert_eq!(rep.exit_code(), 0);
}

#[test]
fn corruption_ladder_input_surface() {
    // TP001: truncated JSON artifact (syntax error, escalated to error
    // in check mode, span inside the file).
    let td = TempDir::new("ladder-tp001").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    std::fs::write(talp.join("exp/talp_2x2_bad.json"), "{\"resources\": ")
        .unwrap();
    let rep = run_check(&input_opts(&talp)).unwrap();
    assert_eq!(codes(&rep), ["TP001"], "{:?}", rep.diagnostics);
    let d = &rep.diagnostics[0];
    assert_eq!(d.severity, Severity::Error, "check escalates TP001");
    assert!(d.span.expect("syntax errors carry spans").start <= 14);
    assert_eq!(rep.exit_code(), 2);
    assert_code(&input_opts(&talp), "TP001", "truncated artifact");

    // TP002: parses as JSON, rejected by the TALP schema.
    let td = TempDir::new("ladder-tp002").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    std::fs::write(talp.join("exp/talp_2x2_odd.json"), "{\"app\": \"x\"}")
        .unwrap();
    assert_code(&input_opts(&talp), "TP002", "non-TALP json");

    // TP050: two runs sharing one effective timestamp.
    let td = TempDir::new("ladder-tp050").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    run(9.0, 1000, "c000") // same commit_timestamp as run0
        .write_file(&talp.join("exp/talp_2x2_twin.json"))
        .unwrap();
    assert_code(&input_opts(&talp), "TP050", "equal timestamps");
}

#[test]
fn corruption_ladder_adapter_formats() {
    // A valid artifact in another registered ingestion format is not a
    // finding: the scanner's TP002 is dropped after the adapter
    // registry vouches for the file, and the mixed tree surfaces as
    // TP022 (info — exit stays 0).
    let td = TempDir::new("ladder-tp022").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    std::fs::write(
        talp.join("exp/bsw_sweep.json"),
        r#"{"application": "bsw", "machine": "mn5",
            "timestamp": "2026-01-01T00:00:00Z",
            "scales": [{"processes": 2, "threads": 2,
                        "time_s": 10.0, "efficiency": 0.9}]}"#,
    )
    .unwrap();
    let rep = run_check(&input_opts(&talp)).unwrap();
    assert_eq!(codes(&rep), ["TP022"], "{:?}", rep.diagnostics);
    assert_eq!(rep.exit_code(), 0, "info never changes the exit code");
    assert_code(&input_opts(&talp), "TP022", "mixed-format tree");

    // TP023: a file two adapters both claim (beeswarm's `scales` next
    // to root-bench's `benchmarks` + `context`) is an error, not a
    // silent pick.
    let td = TempDir::new("ladder-tp023").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    std::fs::write(
        talp.join("exp/mystery.json"),
        r#"{"scales": [], "context": {}, "benchmarks": []}"#,
    )
    .unwrap();
    let rep = run_check(&input_opts(&talp)).unwrap();
    assert_eq!(codes(&rep), ["TP023"], "{:?}", rep.diagnostics);
    assert_eq!(rep.exit_code(), 2, "ambiguity is an error");
    assert_code(&input_opts(&talp), "TP023", "ambiguous format");

    // TP024: recognized by exactly one adapter but broken (beeswarm
    // without its mandatory timestamp) — sharper than a generic TP002.
    let td = TempDir::new("ladder-tp024").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    std::fs::write(
        talp.join("exp/bsw_broken.json"),
        r#"{"scales": [{"processes": 2, "time_s": 3.0,
                        "efficiency": 0.5}]}"#,
    )
    .unwrap();
    let rep = run_check(&input_opts(&talp)).unwrap();
    assert_eq!(codes(&rep), ["TP024"], "{:?}", rep.diagnostics);
    assert_eq!(rep.exit_code(), 2, "a broken artifact is an error");
    assert_code(&input_opts(&talp), "TP024", "recognized but broken");
}

#[test]
fn corruption_ladder_store_surface() {
    let base = |name: &str| -> (TempDir, PathBuf) {
        let td = TempDir::new(name).unwrap();
        let talp = td.path().join("talp");
        build_tree(&talp);
        let store = td.path().join("store");
        run_cli(&format!(
            "ingest --input {} --store {}",
            talp.display(),
            store.display()
        ))
        .unwrap();
        (td, store)
    };
    let shard = |store: &Path| store.join("shards/exp__2x2.jsonl");

    // TP010: manifest gone.
    let (_td, store) = base("ladder-tp010");
    std::fs::remove_file(store.join(".talp-store.json")).unwrap();
    assert_code(&store_opts(&store), "TP010", "missing manifest");

    // TP011: manifest from the future.
    let (_td, store) = base("ladder-tp011");
    std::fs::write(store.join(".talp-store.json"), "{\"version\": 999}\n")
        .unwrap();
    assert_code(&store_opts(&store), "TP011", "version skew");

    // TP012: a corrupt interior record (newline-terminated, so it is
    // ordinary damage — an *unterminated* final line is TP025 below).
    let (_td, store) = base("ladder-tp012");
    let mut bytes = std::fs::read(shard(&store)).unwrap();
    bytes.extend_from_slice(b"{\"hash\": \"tr\n");
    std::fs::write(shard(&store), &bytes).unwrap();
    assert_code(&store_opts(&store), "TP012", "corrupt shard record");

    // TP014: a stray non-store file among the shards.
    let (_td, store) = base("ladder-tp014");
    std::fs::write(store.join("shards/notes.txt"), "x").unwrap();
    assert_code(&store_opts(&store), "TP014", "stray shard file");

    // TP025: a torn final record — the signature of an append that
    // crashed mid-write — and `store fsck --repair` healing it.
    let (_td, store) = base("ladder-tp025");
    let mut bytes = std::fs::read(shard(&store)).unwrap();
    bytes.extend_from_slice(b"{\"hash\": \"tr");
    std::fs::write(shard(&store), &bytes).unwrap();
    assert_code(&store_opts(&store), "TP025", "torn final record");
    assert_eq!(
        run_cli(&format!("store fsck --store {}", store.display()))
            .unwrap(),
        1,
        "dry-run fsck exits 1 while errors remain"
    );
    assert_eq!(
        run_cli(&format!(
            "store fsck --store {} --repair",
            store.display()
        ))
        .unwrap(),
        0,
        "--repair heals the torn tail"
    );
    let rep = run_check(&store_opts(&store)).unwrap();
    assert_eq!(codes(&rep), Vec::<&str>::new(), "{:?}", rep.diagnostics);

    // TP026: interrupted-operation residue (a `.tmp` staging file and
    // an empty shard), warnings with the fsck fix-it.
    let (_td, store) = base("ladder-tp026");
    std::fs::write(store.join("shards/exp__2x2.jsonl.tmp"), "x").unwrap();
    std::fs::write(store.join("shards/late__4x4.jsonl"), "").unwrap();
    assert_code(&store_opts(&store), "TP026", "crash residue");
    let rep = run_check(&store_opts(&store)).unwrap();
    assert_eq!(rep.exit_code(), 1, "residue alone is a warning");
    assert_eq!(
        run_cli(&format!(
            "store fsck --store {} --repair",
            store.display()
        ))
        .unwrap(),
        0
    );
    let rep = run_check(&store_opts(&store)).unwrap();
    assert_eq!(codes(&rep), Vec::<&str>::new(), "{:?}", rep.diagnostics);

    // TP015: one record stored twice.  Growing the shard behind the
    // store's back also leaves the CLI-written sidecar stale (TP017).
    let (_td, store) = base("ladder-tp015");
    let text = std::fs::read_to_string(shard(&store)).unwrap();
    let first = text.lines().next().unwrap().to_string();
    std::fs::write(shard(&store), format!("{text}{first}\n")).unwrap();
    assert_code(&store_opts(&store), "TP015", "duplicate record");
    assert_code(&store_opts(&store), "TP017", "sidecar went stale");

    // TP018: superseding two of three artifacts leaves the shard 2/5
    // dead — past the 0.25 compaction threshold.
    let (td, store) = base("ladder-tp018");
    let talp = td.path().join("talp");
    for i in 1..3 {
        run(20.0 + i as f64, 5000 + i as i64 * 100, &format!("d{i:03}"))
            .write_file(&talp.join(format!("exp/talp_2x2_run{i}.json")))
            .unwrap();
    }
    run_cli(&format!(
        "ingest --input {} --store {}",
        talp.display(),
        store.display()
    ))
    .unwrap();
    assert_code(&store_opts(&store), "TP018", "dead bytes past threshold");

    // TP016: identical bytes ingested from two source paths (info —
    // exit stays 0).  The copy lives under another *experiment* so the
    // two runs land in separate histories — same-experiment copies
    // would also trip TP050 (identical content means identical
    // timestamps) and muddy the exit-code assert.
    let td = TempDir::new("ladder-tp016").unwrap();
    let talp = td.path().join("talp");
    build_tree(&talp);
    std::fs::create_dir_all(talp.join("exp2")).unwrap();
    std::fs::copy(
        talp.join("exp/talp_2x2_run0.json"),
        talp.join("exp2/talp_2x2_copy.json"),
    )
    .unwrap();
    let store = td.path().join("store");
    run_cli(&format!(
        "ingest --input {} --store {}",
        talp.display(),
        store.display()
    ))
    .unwrap();
    let rep = run_check(&store_opts(&store)).unwrap();
    assert!(codes(&rep).contains(&"TP016"), "{:?}", rep.diagnostics);
    assert_eq!(rep.exit_code(), 0, "info never changes the exit code");
}

#[test]
fn corruption_ladder_policy_cache_report_bench() {
    let td = TempDir::new("ladder-files").unwrap();
    let file = |name: &str, content: &str| -> PathBuf {
        let p = td.path().join(name);
        std::fs::write(&p, content).unwrap();
        p
    };
    let policy_of = |p: PathBuf| CheckOptions {
        policy: Some(p),
        ..Default::default()
    };

    // TP003: syntactically broken policy (span) and semantic typo.
    let bad = file("p-syntax.json", "{\"version\": 1, ");
    assert_code(&policy_of(bad), "TP003", "truncated policy");
    let typo =
        file("p-typo.json", r#"{"version":1,"defaults":{"windw":3}}"#);
    assert_code(&policy_of(typo), "TP003", "typo policy");

    // TP040/TP041: referentially dead rules against a real corpus.
    let talp = td.path().join("talp");
    build_tree(&talp);
    let dead = file(
        "p-dead.json",
        r#"{"version":1,
            "rules":[{"region":"nonexistent"}],
            "allow":[{"experiment":"gone*","reason":"r"}]}"#,
    );
    let opts = CheckOptions {
        input: Some(talp),
        policy: Some(dead),
        ..Default::default()
    };
    assert_code(&opts, "TP040", "dead rule");
    assert_code(&opts, "TP041", "dead allow entry");

    // TP020/TP021: cache version skew vs invalid cache.
    let skew = file("cache-skew.json", "{\"version\": 999}\n");
    let cache_of = |p: PathBuf| CheckOptions {
        cache: Some(p),
        ..Default::default()
    };
    assert_code(&cache_of(skew), "TP020", "cache version skew");
    let junk = file("cache-junk.json", "not json at all");
    assert_code(&cache_of(junk), "TP021", "invalid cache");

    // TP030/TP031/TP013: report schema skew, shape error, missing file.
    let report_of = |p: PathBuf| CheckOptions {
        report: Some(p),
        ..Default::default()
    };
    let skew = file("report-skew.json", "{\"schema_version\": 999}");
    assert_code(&report_of(skew), "TP030", "report schema skew");
    let shape = file("report-shape.json", "[1, 2");
    assert_code(&report_of(shape), "TP031", "report shape error");
    assert_code(
        &report_of(td.path().join("no-such-report.json")),
        "TP013",
        "missing report",
    );

    // TP060: an all-zero bench baseline, plus TP001 for a torn line.
    let bench_of = |p: PathBuf| CheckOptions {
        bench: Some(p),
        ..Default::default()
    };
    let zeros = file(
        "bench-zero.json",
        "{\"bench\": \"_meta\", \"note\": \"n\"}\n\
         {\"bench\": \"scan\", \"elapsed_s\": 0}\n",
    );
    assert_code(&bench_of(zeros), "TP060", "unmeasured baseline");
    let torn = file(
        "bench-torn.json",
        "{\"bench\": \"scan\", \"elapsed_s\": 0.5}\n{\"bench\": ",
    );
    assert_code(&bench_of(torn), "TP001", "torn bench line");
}

#[test]
fn output_is_byte_identical_across_jobs() {
    let td = TempDir::new("check-jobs").unwrap();
    let talp = td.path().join("talp");
    // Several experiments so the parallel scan actually fans out.
    for exp in ["alpha", "beta", "gamma"] {
        for i in 0..3 {
            run(10.0 + i as f64, 1000 + i as i64 * 100, &format!("c{i:03}"))
                .write_file(
                    &talp.join(format!("{exp}/talp_2x2_run{i}.json")),
                )
                .unwrap();
        }
    }
    // Seed findings of every severity: a torn artifact, a dead rule,
    // a zero bench baseline.
    std::fs::write(talp.join("beta/talp_2x2_bad.json"), "{\"resources")
        .unwrap();
    let policy = td.path().join("policy.json");
    std::fs::write(
        &policy,
        r#"{"version":1,"rules":[{"region":"nonexistent"}]}"#,
    )
    .unwrap();
    let bench = td.path().join("bench.json");
    std::fs::write(&bench, "{\"bench\": \"scan\", \"elapsed_s\": 0}\n")
        .unwrap();

    let opts = |jobs: usize| CheckOptions {
        input: Some(talp.clone()),
        policy: Some(policy.clone()),
        bench: Some(bench.clone()),
        jobs,
        ..Default::default()
    };
    let rep1 = run_check(&opts(1)).unwrap();
    let rep4 = run_check(&opts(4)).unwrap();
    assert_eq!(rep1.render_text(), rep4.render_text());
    assert_eq!(sarif::render(&rep1), sarif::render(&rep4));
    assert_eq!(rep1.exit_code(), 2, "the torn artifact is an error");
}

// ---------------------------------------------------------------- golden

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name)
}

/// Fixed synthetic report — real runs embed temp paths, so the golden
/// pins the *rendering*, not a particular filesystem.
fn golden_report() -> CheckReport {
    let mut rep = CheckReport::new();
    rep.push(
        Diagnostic::error(
            "TP001",
            "talp/exp/bad.json",
            "invalid JSON: json error at byte 12: expected value — \
             skipped",
        )
        .with_span(Span { start: 12, len: 1 }),
    );
    rep.push(
        Diagnostic::warning(
            "TP040",
            "policy.json",
            "rules[0] (experiment 'salpha', config '*', region 'solve') \
             matches nothing in the corpus",
        )
        .with_hint("fix the pattern or delete the dead rule"),
    );
    rep.push(Diagnostic::info(
        "TP016",
        "store",
        "content hash 00000000deadbeef is stored under 2 source paths \
         (exp/a.json, exp/b.json) — each counts as its own history point",
    ));
    rep.push(
        Diagnostic::warning(
            "TP017",
            "store/shards/exp__2x2.jsonl.idx",
            "stale: shard is 2208 bytes but the index was built from \
             1296 — queries fall back to the sequential scan",
        )
        .with_hint(
            "indexes rebuild on demand — the next `talp-pages store \
             query` heals this sidecar",
        ),
    );
    rep.push(
        Diagnostic::info(
            "TP018",
            "store/shards/exp__2x2.jsonl",
            "dead-byte ratio 0.41 exceeds the compaction threshold 0.25 \
             (912 of 2208 bytes are superseded, duplicate or corrupt)",
        )
        .with_hint(
            "`talp-pages store compact` rewrites shards past the \
             threshold",
        ),
    );
    rep.push(
        Diagnostic::error(
            "TP025",
            "store/shards/exp__2x2.jsonl",
            "torn final record at line 4 (json error at byte 2100: \
             unexpected end of input) — an append was interrupted \
             mid-write",
        )
        .with_span(Span { start: 2100, len: 1 })
        .with_hint(
            "`talp-pages store fsck --repair` truncates the torn tail \
             back to the last intact record",
        ),
    );
    rep.push(
        Diagnostic::warning(
            "TP026",
            "store/shards/exp__2x2.jsonl.tmp",
            "interrupted-operation residue in shards/ (a `.tmp` staging \
             file whose rename never happened) — the loader ignores it",
        )
        .with_hint(
            "`talp-pages store fsck --repair` removes crash residue",
        ),
    );
    rep.push(
        Diagnostic::info(
            "TP022",
            "talp",
            "tree mixes 2 ingestion formats (beeswarm 1, talp 3)",
        )
        .with_hint(
            "intentional mixes are fine; pin one with `ingest --format \
             <name>` to reject strays",
        ),
    );
    rep.push(
        Diagnostic::error(
            "TP023",
            "talp/exp/mystery.json",
            "ambiguous format — detected as both 'root-bench' and \
             'beeswarm'",
        )
        .with_hint(
            "pass an explicit --format to ingest, or remove the \
             colliding top-level keys",
        ),
    );
    rep.push(
        Diagnostic::error(
            "TP024",
            "talp/exp/bsw_broken.json",
            "recognized as a 'beeswarm' artifact but it fails to parse: \
             parsing talp/exp/bsw_broken.json: missing/bad timestamp",
        )
        .with_hint("fix the file or remove it from the tree"),
    );
    rep.sort();
    rep
}

#[test]
fn sarif_output_matches_golden() {
    let got = sarif::render(&golden_report());
    let path = golden_path("check.sarif");
    if std::env::var_os("UPDATE_GOLDEN").is_some() {
        std::fs::write(&path, &got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden check.sarif: {e}"));
    assert_eq!(
        got, want,
        "SARIF drift; if intentional, regenerate with \
         UPDATE_GOLDEN=1 cargo test --test check_cli"
    );
}

// ------------------------------------------------------------ properties

/// The analyzer never panics on corrupted bytes, reports spans that
/// stay inside the damaged file, and renders deterministically.
#[test]
fn check_never_panics_and_spans_stay_in_bounds() {
    let base = {
        let td = TempDir::new("check-prop-base").unwrap();
        let p = td.path().join("base.json");
        run(10.0, 1000, "c000").write_file(&p).unwrap();
        std::fs::read(&p).unwrap()
    };
    propcheck::check("check survives corrupted artifacts", 48, |rng| {
        let mut bytes = base.clone();
        match rng.below(3) {
            0 => bytes.truncate(rng.below(bytes.len() as u64) as usize),
            1 => {
                let i = rng.below(bytes.len() as u64) as usize;
                bytes[i] = rng.below(256) as u8;
            }
            _ => {
                let i = rng.below(bytes.len() as u64) as usize;
                bytes.splice(i..i, *b"{]\"\x00");
            }
        }
        let td = TempDir::new("check-prop").map_err(|e| e.to_string())?;
        let talp = td.path().join("talp");
        let file = talp.join("exp/talp_2x2_run0.json");
        std::fs::create_dir_all(file.parent().unwrap())
            .map_err(|e| e.to_string())?;
        std::fs::write(&file, &bytes).map_err(|e| e.to_string())?;

        let rep =
            run_check(&input_opts(&talp)).map_err(|e| e.to_string())?;
        for d in &rep.diagnostics {
            if let Some(span) = d.span {
                if span.start > bytes.len() {
                    return Err(format!(
                        "span {} beyond file of {} bytes: {d}",
                        span.start,
                        bytes.len()
                    ));
                }
            }
        }
        let again =
            run_check(&input_opts(&talp)).map_err(|e| e.to_string())?;
        if rep.render_text() != again.render_text() {
            return Err("nondeterministic text output".into());
        }
        Ok(())
    });
}
