//! Golden tests for the `init-ci` pipeline templates: the full
//! rendered YAML (both flavors, including the gate job) is compared
//! byte-for-byte against checked-in golden files, so any template
//! drift shows up as a reviewable diff.
//!
//! To regenerate after an intentional change:
//! `UPDATE_GOLDEN=1 cargo test --test templates_golden`

use std::path::PathBuf;

use talp_pages::ci::{templates, MatrixSpec};

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name)
}

fn check(name: &str, got: &str) {
    let path = golden_path(name);
    if std::env::var_os("UPDATE_GOLDEN").is_some() {
        std::fs::write(&path, got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden {name}: {e}"));
    assert_eq!(
        got, want,
        "template drift for {name}; if intentional, regenerate with \
         UPDATE_GOLDEN=1 cargo test --test templates_golden"
    );
}

fn render_gitlab() -> String {
    templates::gitlab_ci_yaml(
        &MatrixSpec::performance_cpu_fast(),
        &["initialize", "timestep"],
        "timestep",
        ".talp-gate.json",
    )
}

fn render_github() -> String {
    templates::github_actions_yaml(
        &MatrixSpec::performance_cpu_fast(),
        &["initialize", "timestep"],
        "timestep",
        ".talp-gate.json",
    )
}

#[test]
fn gitlab_template_matches_golden() {
    let y = render_gitlab();
    // Structural anchors first (clearer failures than a full diff).
    assert!(y.contains("stages: [check, performance, deploy, gate]"));
    assert!(y.contains("talp-check:"));
    assert!(y.contains("talp-gate:"));
    assert!(y.contains("junit: gate/gate.xml"));
    check("gitlab-ci.yml", &y);
}

#[test]
fn github_template_matches_golden() {
    let y = render_github();
    assert!(y.contains("talp-gate:"));
    assert!(y.contains("talp-pages gate --input talp"));
    check("github-actions.yml", &y);
}

#[test]
fn templates_render_reproducibly() {
    assert_eq!(render_gitlab(), render_gitlab());
    assert_eq!(render_github(), render_github());
}
