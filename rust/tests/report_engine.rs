//! Report-engine integration tests over the staged Session pipeline:
//! golden-file determinism of the parallel path (`jobs = 1` vs
//! `jobs = 4` byte-for-byte across the whole output tree, `report.json`
//! included), exact badge bytes, and the incremental-cache contract (a
//! warm rerun over a fixture with >= 8 experiments parses zero
//! unchanged artifacts).

use std::collections::BTreeMap;
use std::path::Path;

use talp_pages::pages::badge;
use talp_pages::pages::cache::CACHE_FILE_NAME;
use talp_pages::session::{self, AnalyzeOptions, EmitSummary, Session};
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;

/// Hand-built run: deterministic numbers, no simulator.  With
/// `elapsed = 10`, `threads = 2` and `useful = 15` per process the
/// parallel efficiency is exactly 15/(2*10) = 0.75.
fn run(
    ranks: u32,
    useful_per_proc: f64,
    elapsed: f64,
    ts: i64,
    commit: &str,
) -> RunData {
    let region = |name: &str, e: f64, scale: f64| RegionData {
        name: name.into(),
        elapsed_s: e,
        visits: 1,
        procs: (0..ranks)
            .map(|r| ProcStats {
                rank: r,
                node: 0,
                elapsed_s: e,
                useful_s: useful_per_proc * scale,
                mpi_s: 0.05 * e,
                mpi_worker_idle_s: 0.05 * e,
                omp_serialization_s: 0.01 * e,
                omp_scheduling_s: 0.01 * e,
                omp_barrier_s: 0.02 * e,
                useful_instructions: 1_000_000 / ranks as u64,
                useful_cycles: 500_000 / ranks as u64,
            })
            .collect(),
    };
    RunData {
        dlb_version: "test".into(),
        app: "golden".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks,
        threads: 2,
        nodes: 1,
        regions: vec![
            region("Global", elapsed, 1.0),
            region("solve", elapsed * 0.6, 0.55),
        ],
        git: Some(GitMeta {
            commit: commit.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

/// Fixture tree: 2 experiments x 3 configs x 2 runs.
fn build_fixture(root: &Path) {
    for exp in ["alpha/strong", "beta/weak"] {
        for ranks in [2u32, 4, 8] {
            for (i, ts) in [(0, 1000i64), (1, 2000)] {
                // Older runs are slightly slower so history is non-flat.
                let elapsed = 10.0 + (1 - i) as f64;
                let useful = 15.0 * elapsed / 10.0;
                run(ranks, useful, elapsed, ts, &format!("c{i}{ranks:02}"))
                    .write_file(&root.join(format!(
                        "{exp}/talp_{ranks}x2_run{i}.json"
                    )))
                    .unwrap();
            }
        }
    }
}

/// Scan + analyze + emit the full site into `out` (cache lives next to
/// the pages, like the CLI default).
fn generate(input: &Path, out: &Path, jobs: usize) -> EmitSummary {
    Session::new(input)
        .jobs(jobs)
        .cache(out.join(CACHE_FILE_NAME))
        .scan()
        .unwrap()
        .analyze(&AnalyzeOptions::default())
        .emit(&mut session::default_emitters(out))
        .unwrap()
}

/// All files under `dir` as (relative path -> bytes).
fn snapshot(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn collect(
        root: &Path,
        dir: &Path,
        out: &mut BTreeMap<String, Vec<u8>>,
    ) {
        for entry in std::fs::read_dir(dir).unwrap().flatten() {
            let p = entry.path();
            if p.is_dir() {
                collect(root, &p, out);
            } else {
                let rel = p
                    .strip_prefix(root)
                    .unwrap()
                    .to_string_lossy()
                    .replace('\\', "/");
                out.insert(rel, std::fs::read(&p).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    collect(dir, dir, &mut out);
    out
}

#[test]
fn jobs_1_and_jobs_4_outputs_are_byte_identical() {
    let input = TempDir::new("golden-in").unwrap();
    build_fixture(input.path());
    let out1 = TempDir::new("golden-out1").unwrap();
    let out4 = TempDir::new("golden-out4").unwrap();

    let s1 = generate(input.path(), out1.path(), 1);
    let s4 = generate(input.path(), out4.path(), 4);
    assert_eq!(s1.experiments, 2);
    assert_eq!(s1.cache_misses, 12);
    assert_eq!(s4.cache_misses, 12);

    let a = snapshot(out1.path());
    let b = snapshot(out4.path());
    assert_eq!(
        a.keys().collect::<Vec<_>>(),
        b.keys().collect::<Vec<_>>(),
        "file sets differ between jobs 1 and jobs 4"
    );
    for (path, bytes) in &a {
        assert_eq!(
            Some(bytes),
            b.get(path),
            "{path} differs between jobs 1 and jobs 4"
        );
    }
    // The golden file set: index + 2 experiment pages + 6 badges +
    // cache + machine-readable report.
    let expected: Vec<&str> = vec![
        ".talp-cache.json",
        "alpha_strong.html",
        "badges/alpha_strong__2x2.svg",
        "badges/alpha_strong__4x2.svg",
        "badges/alpha_strong__8x2.svg",
        "badges/beta_weak__2x2.svg",
        "badges/beta_weak__4x2.svg",
        "badges/beta_weak__8x2.svg",
        "beta_weak.html",
        "index.html",
        "report.json",
    ];
    assert_eq!(a.keys().map(String::as_str).collect::<Vec<_>>(), expected);
}

#[test]
fn index_page_and_badge_golden_bytes() {
    let input = TempDir::new("golden-in2").unwrap();
    build_fixture(input.path());
    let out = TempDir::new("golden-out2").unwrap();
    generate(input.path(), out.path(), 0);

    // Index golden line: the experiment entry with its counts.
    let index =
        std::fs::read_to_string(out.path().join("index.html")).unwrap();
    assert!(index.contains(
        "<li><a href=\"alpha_strong.html\">alpha/strong</a> \
         — 3 configs, 6 runs</li>"
    ));
    assert!(index.contains("2 experiment(s) found under"));

    // Experiment page golden anchors.
    let page =
        std::fs::read_to_string(out.path().join("alpha_strong.html"))
            .unwrap();
    assert!(page.contains("<h1>alpha/strong</h1>"));
    assert!(page.contains("Scaling efficiency — region <code>Global</code>"));
    assert!(page.contains("Scaling efficiency — region <code>solve</code>"));
    assert!(page.contains("Time evolution — 2x2 (2 runs)"));
    assert!(page.contains("<code>c102</code>"), "latest commit annotated");

    // Badge byte-for-byte: the latest 2x2 run has PE exactly 0.75.
    let got = std::fs::read_to_string(
        out.path().join("badges/alpha_strong__2x2.svg"),
    )
    .unwrap();
    let want = badge::parallel_efficiency_badge("Global", "2x2", 0.75);
    assert_eq!(got, want, "badge SVG is not byte-exact");
    assert!(got.contains("0.75"));
}

#[test]
fn warm_rerun_on_eight_experiments_parses_nothing() {
    // Acceptance criterion: >= 8 experiments, warm rerun parses zero
    // unchanged artifacts, verified by the EmitSummary counters.
    let input = TempDir::new("warm8-in").unwrap();
    let mut total_files = 0usize;
    for e in 0..8 {
        for ranks in [2u32, 4] {
            run(ranks, 15.0, 10.0, 1000, &format!("e{e}r{ranks}"))
                .write_file(&input.path().join(format!(
                    "exp_{e}/talp_{ranks}x2.json"
                )))
                .unwrap();
            total_files += 1;
        }
    }
    let out = TempDir::new("warm8-out").unwrap();

    let cold = generate(input.path(), out.path(), 4);
    assert_eq!(cold.experiments, 8);
    assert_eq!(cold.cache_hits, 0);
    assert_eq!(cold.cache_misses, total_files);
    let before = snapshot(out.path());

    let warm = generate(input.path(), out.path(), 4);
    assert_eq!(warm.cache_hits, total_files, "warm run must hit for all");
    assert_eq!(warm.cache_misses, 0, "warm run must parse nothing");
    let after = snapshot(out.path());
    assert_eq!(before, after, "warm rerun changed the site");

    // Adding one new artifact only parses that artifact.
    run(2, 15.0, 10.0, 3000, "fresh")
        .write_file(&input.path().join("exp_0/talp_2x2_new.json"))
        .unwrap();
    let mixed = generate(input.path(), out.path(), 4);
    assert_eq!(mixed.cache_hits, total_files);
    assert_eq!(mixed.cache_misses, 1);
}
