//! Acceptance tests for the indexed store backend (ISSUE 7):
//!
//! * a store written without sidecars still opens and queries — the
//!   indexes rebuild on demand and are healed onto disk;
//! * a corrupted sidecar degrades loudly (TP017) and a
//!   boundary-truncated one degrades silently, both with results
//!   byte-identical to the full-scan control — never hidden records;
//! * `store query` output is byte-identical across `--jobs 1`/`4` and
//!   across `--no-index`;
//! * `compact` under supersede keeps `report --store` byte-identical
//!   to a direct artifact scan.

use std::path::{Path, PathBuf};

use talp_pages::cli;
use talp_pages::store::{
    ingest_dir, sidecar_path, QuerySpec, RunStore,
};
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

/// Hand-built run with exact decimal inputs, same shape as the
/// store-roundtrip fixture.
fn run(ranks: u32, useful: f64, elapsed: f64, ts: i64, sha: &str) -> RunData {
    RunData {
        dlb_version: "test".into(),
        app: "store-q".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks,
        threads: 2,
        nodes: 1,
        regions: vec![RegionData {
            name: "Global".into(),
            elapsed_s: elapsed,
            visits: 1,
            procs: (0..ranks)
                .map(|r| ProcStats {
                    rank: r,
                    elapsed_s: elapsed,
                    useful_s: useful,
                    mpi_s: 0.05 * elapsed,
                    ..Default::default()
                })
                .collect(),
        }],
        git: Some(GitMeta {
            commit: sha.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

/// Three 2x2 runs (so one shard has a multi-line history worth
/// truncating an index of) plus one 4x2 run in a second shard.
fn build_fixture(root: &Path) {
    run(2, 24.0, 16.0, 1000, "aaaa0001")
        .write_file(&root.join("exp/talp_2x2_run0.json"))
        .unwrap();
    run(2, 18.0, 12.0, 2000, "bbbb0002")
        .write_file(&root.join("exp/talp_2x2_run1.json"))
        .unwrap();
    run(2, 15.0, 10.0, 3000, "cccc0003")
        .write_file(&root.join("exp/talp_2x2_run2.json"))
        .unwrap();
    run(4, 15.0, 10.0, 3000, "cccc0003")
        .write_file(&root.join("exp/talp_4x2_run0.json"))
        .unwrap();
}

fn read(p: PathBuf) -> String {
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

fn render(out: &talp_pages::store::QueryOutcome) -> String {
    out.records.iter().map(|r| r.to_line() + "\n").collect()
}

#[test]
fn unindexed_store_queries_correctly_and_heals_sidecars() {
    let td = TempDir::new("store-q-heal").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    let root = td.path().join("store");
    {
        // Library-level ingest writes shards but no sidecars — the
        // backward-compat shape of every pre-index store.
        let mut store = RunStore::create_or_open(&root).unwrap();
        assert_eq!(ingest_dir(&mut store, &input).unwrap().stored, 4);
    }
    let shard = root.join("shards/exp__2x2.jsonl");
    assert!(shard.exists());
    assert!(!sidecar_path(&shard).exists(), "no sidecars yet");

    let spec = QuerySpec { last: Some(1), ..Default::default() };
    let cold = RunStore::query(&root, 0, &spec).unwrap();
    assert_eq!(cold.records.len(), 2, "last-1 per (experiment, config)");
    assert_eq!(cold.stats.indexes_rebuilt, 2);
    assert_eq!(cold.stats.live_runs, 4);
    assert!(cold.warnings.is_empty(), "rebuild-on-demand is silent");
    assert!(
        sidecar_path(&shard).exists(),
        "the query heals sidecars onto disk"
    );

    // Healed store: fresh indexes, and the decode counter proves the
    // query touched only what it returned.
    let warm = RunStore::query(&root, 0, &spec).unwrap();
    assert_eq!(warm.stats.indexes_fresh, 2);
    assert_eq!(warm.stats.indexes_rebuilt, 0);
    assert_eq!(warm.stats.decoded_lines, warm.stats.matched_runs);
    assert_eq!(render(&warm), render(&cold));
    assert_eq!(
        render(&warm),
        render(&RunStore::query_full_scan(&root, 0, &spec).unwrap())
    );
}

#[test]
fn damaged_sidecars_degrade_to_full_scan_never_hide_records() {
    let td = TempDir::new("store-q-damage").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    let root = td.path().join("store");
    // CLI ingest refreshes sidecars, so the store starts fully indexed.
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            input.display(),
            root.display()
        ))
        .unwrap(),
        0
    );
    let shard = root.join("shards/exp__2x2.jsonl");
    let sidecar = sidecar_path(&shard);
    let good = read(sidecar.clone());
    let spec = QuerySpec::default();
    let control = render(&RunStore::query_full_scan(&root, 0, &spec).unwrap());

    // Corrupt sidecar: loud TP017, identical results, healed on disk.
    std::fs::write(&sidecar, "{\"index_version\": ").unwrap();
    let out = RunStore::query(&root, 0, &spec).unwrap();
    assert_eq!(render(&out), control);
    let tp017: Vec<_> =
        out.warnings.iter().filter(|d| d.code == "TP017").collect();
    assert_eq!(tp017.len(), 1, "{:?}", out.warnings);
    assert!(
        tp017[0].message.contains("unusable index sidecar"),
        "{}",
        tp017[0].message
    );
    assert_eq!(read(sidecar.clone()), good, "the rebuild healed it");

    // Truncation at an entry-line boundary: the sidecar still parses
    // and its header still matches the shard, but its tail entries are
    // gone.  Coverage detection demotes it to stale — a silent rebuild
    // with every record present, not a short answer.
    let truncated: String = {
        let mut lines: Vec<&str> =
            good.lines().filter(|l| !l.is_empty()).collect();
        assert!(lines.len() >= 3, "header + >=2 entries: {good}");
        lines.pop();
        lines.join("\n") + "\n"
    };
    std::fs::write(&sidecar, truncated).unwrap();
    let out = RunStore::query(&root, 0, &spec).unwrap();
    assert_eq!(render(&out), control, "truncated index must not drop runs");
    assert!(
        out.warnings.iter().all(|d| d.code != "TP017"),
        "boundary truncation reads as stale, not corrupt: {:?}",
        out.warnings
    );
    assert_eq!(read(sidecar), good, "healed again");
}

#[test]
fn cli_store_query_is_deterministic_across_jobs_and_index_state() {
    let td = TempDir::new("store-q-jobs").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    let root = td.path().join("store");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            input.display(),
            root.display()
        ))
        .unwrap(),
        0
    );

    let mut outputs = Vec::new();
    for (tag, flags) in [
        ("j1", "--jobs 1"),
        ("j4", "--jobs 4"),
        ("noidx", "--no-index --jobs 4"),
    ] {
        let out = td.path().join(format!("q-{tag}.jsonl"));
        assert_eq!(
            run_cli(&format!(
                "store query --store {} --experiment exp --last 2 \
                 --output {} {flags}",
                root.display(),
                out.display()
            ))
            .unwrap(),
            0
        );
        outputs.push(read(out));
    }
    assert!(!outputs[0].is_empty());
    assert_eq!(outputs[0].lines().count(), 3, "last 2 of 2x2 + 1 of 4x2");
    assert_eq!(outputs[0], outputs[1], "--jobs 1 vs --jobs 4");
    assert_eq!(outputs[0], outputs[2], "indexed vs --no-index");
}

#[test]
fn compact_under_supersede_keeps_store_report_identical_to_direct() {
    let td = TempDir::new("store-q-compact").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    let root = td.path().join("store");
    let ingest = format!(
        "ingest --input {} --store {}",
        input.display(),
        root.display()
    );
    assert_eq!(run_cli(&ingest).unwrap(), 0);

    // Re-measured artifacts at the same paths: the store supersedes in
    // place, a direct scan simply reads the new content.  Two of five
    // shard lines go dead — ratio 0.4, past the 0.25 threshold (one of
    // four would sit exactly *at* it, which the strict `>` skips).
    run(2, 16.0, 10.5, 2500, "eeee0005")
        .write_file(&input.join("exp/talp_2x2_run1.json"))
        .unwrap();
    run(2, 14.0, 9.0, 4000, "dddd0004")
        .write_file(&input.join("exp/talp_2x2_run2.json"))
        .unwrap();
    assert_eq!(run_cli(&ingest).unwrap(), 0);

    let report = |flag: &str, src: &Path, out: &Path| {
        assert_eq!(
            run_cli(&format!(
                "report {flag} {} --output {} --format json",
                src.display(),
                out.display()
            ))
            .unwrap(),
            0
        );
        read(out.join("report.json"))
    };
    let direct = report("--input", &input, &td.path().join("site-direct"));
    assert_eq!(
        direct,
        report("--store", &root, &td.path().join("site-pre")),
        "superseded store differs from direct scan before compaction"
    );

    // The superseded line pushes the 2x2 shard past the dead-byte
    // threshold; compaction rewrites it (and refreshes the sidecar).
    assert_eq!(
        run_cli(&format!("store compact --store {}", root.display()))
            .unwrap(),
        0
    );
    let shard_text = read(root.join("shards/exp__2x2.jsonl"));
    assert_eq!(shard_text.lines().count(), 3, "dead lines dropped");
    assert!(!shard_text.contains("bbbb0002"), "old record rewritten away");
    assert_eq!(
        direct,
        report("--store", &root, &td.path().join("site-post")),
        "compaction changed the report"
    );
}
