//! Acceptance tests for the multi-format ingestion API (ISSUE 9):
//!
//! * the committed per-adapter fixtures admit through the CLI into
//!   one mixed store, with `--format` pinning and auto-detection
//!   agreeing on the result;
//! * `report --store` over a mixed corpus is byte-identical across
//!   `--jobs 1/4` and across cold/warm cache runs;
//! * `talp-pages sim` corpora are byte-reproducible from the seed
//!   (and actually differ under another seed), in foreign formats
//!   too.

use std::path::{Path, PathBuf};

use talp_pages::cli;
use talp_pages::store::RunStore;
use talp_pages::util::fs::TempDir;

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// A mixed artifact tree: three native talp runs from the simulator
/// plus the committed ROOT-bench and BeeSwarm fixtures under `ci/`.
fn build_mixed_tree(tree: &Path) {
    assert_eq!(
        run_cli(&format!(
            "sim --output {} --seed 11 --runs 3 --axes weak-scaling",
            tree.display()
        ))
        .unwrap(),
        0
    );
    std::fs::create_dir_all(tree.join("ci")).unwrap();
    std::fs::copy(fixture("root_bench.json"), tree.join("ci/bench.json"))
        .unwrap();
    std::fs::copy(fixture("beeswarm.json"), tree.join("ci/sweep.json"))
        .unwrap();
}

#[test]
fn fixtures_admit_through_cli_into_one_store() {
    let td = TempDir::new("adapters-cli").unwrap();
    let tree = td.path().join("artifacts");
    build_mixed_tree(&tree);
    let store = td.path().join("store");

    // Auto-detection admits all three formats in one pass: 3 talp
    // runs, 1 root-bench pseudo-run, 3 beeswarm scale points.
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            tree.display(),
            store.display()
        ))
        .unwrap(),
        0
    );
    assert_eq!(RunStore::open(&store).unwrap().len(), 7);

    // A second pass is warm for every format: multi-run files skip at
    // the file-hash level too.
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            tree.display(),
            store.display()
        ))
        .unwrap(),
        0
    );
    assert_eq!(RunStore::open(&store).unwrap().len(), 7);

    // Pinning --format root-bench admits only the root-bench fixture
    // from the ci/ folder; the beeswarm file degrades to a skip.
    let pinned = td.path().join("store-pinned");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {} --format root-bench",
            tree.join("ci").display(),
            pinned.display()
        ))
        .unwrap(),
        0
    );
    assert_eq!(RunStore::open(&pinned).unwrap().len(), 1);

    // An unknown format name is a hard CLI error.
    assert!(run_cli(&format!(
        "ingest --input {} --store {} --format protobuf",
        tree.display(),
        td.path().join("store-bad").display()
    ))
    .is_err());
}

#[test]
fn mixed_store_report_is_byte_identical_across_jobs_and_warmth() {
    let td = TempDir::new("adapters-report").unwrap();
    let tree = td.path().join("artifacts");
    build_mixed_tree(&tree);
    let store = td.path().join("store");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            tree.display(),
            store.display()
        ))
        .unwrap(),
        0
    );

    let report_with = |jobs: usize, out: &Path| -> String {
        assert_eq!(
            run_cli(&format!(
                "report --store {} --output {} --format json --jobs {jobs}",
                store.display(),
                out.display()
            ))
            .unwrap(),
            0
        );
        std::fs::read_to_string(out.join("report.json")).unwrap()
    };

    let site1 = td.path().join("site-jobs1");
    let site4 = td.path().join("site-jobs4");
    let cold = report_with(1, &site1);
    assert_eq!(
        cold,
        report_with(4, &site4),
        "mixed-store report must not depend on --jobs"
    );
    // Second run over the same output dir hits the metrics cache.
    let warm = report_with(1, &site1);
    assert_eq!(cold, warm, "warm report must equal the cold one");
    // All three formats actually contribute experiments.
    for exp in ["weak-scaling", "ci"] {
        assert!(cold.contains(exp), "missing experiment {exp}:\n{cold}");
    }
}

#[test]
fn sim_corpora_are_byte_reproducible_from_the_seed() {
    let td = TempDir::new("sim-determinism").unwrap();
    let gen = |dir: &str, seed: u64| -> PathBuf {
        let out = td.path().join(dir);
        assert_eq!(
            run_cli(&format!(
                "sim --output {} --seed {seed} --runs 2 \
                 --axes weak-scaling --axes step --format beeswarm",
                out.display()
            ))
            .unwrap(),
            0
        );
        out
    };
    let a = gen("a", 42);
    let b = gen("b", 42);
    let c = gen("c", 43);

    let snapshot = |root: &Path| -> Vec<(String, Vec<u8>)> {
        let mut files = Vec::new();
        for axis in ["weak-scaling", "step"] {
            for i in 0..2 {
                let rel = format!("{axis}/run_{i}.json");
                files.push((
                    rel.clone(),
                    std::fs::read(root.join(&rel)).unwrap(),
                ));
            }
        }
        files
    };
    assert_eq!(
        snapshot(&a),
        snapshot(&b),
        "same seed must reproduce byte-for-byte"
    );
    assert_ne!(snapshot(&a), snapshot(&c), "another seed must differ");
}
