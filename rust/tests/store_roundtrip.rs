//! Acceptance tests for the persistent cross-commit run store
//! (ISSUE 4):
//!
//! * `talp-pages ingest` + `report --store` produces a `report.json`
//!   byte-identical to a direct `report --input` scan over the same
//!   runs (and the same holds for the gate verdict files);
//! * a warm second ingest parses zero artifacts;
//! * a truncated/corrupt shard record is skipped with a warning that
//!   surfaces in the report, not a failed report;
//! * an unknown store version is rejected outright.

use std::path::{Path, PathBuf};

use talp_pages::cli;
use talp_pages::store::{ingest_dir, RunStore, MANIFEST_FILE_NAME};
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

/// Hand-built run with exact decimal inputs — no simulator, so both
/// scan paths reduce the very same artifacts.
fn run(ranks: u32, useful: f64, elapsed: f64, ts: i64, sha: &str) -> RunData {
    RunData {
        dlb_version: "test".into(),
        app: "store-rt".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks,
        threads: 2,
        nodes: 1,
        regions: vec![RegionData {
            name: "Global".into(),
            elapsed_s: elapsed,
            visits: 1,
            procs: (0..ranks)
                .map(|r| ProcStats {
                    rank: r,
                    elapsed_s: elapsed,
                    useful_s: useful,
                    mpi_s: 0.05 * elapsed,
                    ..Default::default()
                })
                .collect(),
        }],
        git: Some(GitMeta {
            commit: sha.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

/// Two configs; the 2x2 history carries a 16 -> 10 elapsed drop so the
/// documents contain detections (identity is meaningful, not vacuous).
fn build_fixture(root: &Path) {
    run(2, 24.0, 16.0, 1000, "slowslow1")
        .write_file(&root.join("exp/talp_2x2_run0.json"))
        .unwrap();
    run(2, 15.0, 10.0, 2000, "fastfast2")
        .write_file(&root.join("exp/talp_2x2_run1.json"))
        .unwrap();
    run(4, 15.0, 10.0, 1000, "slowslow1")
        .write_file(&root.join("exp/talp_4x2_run0.json"))
        .unwrap();
    run(4, 15.0, 10.0, 2000, "fastfast2")
        .write_file(&root.join("exp/talp_4x2_run1.json"))
        .unwrap();
}

fn read(p: PathBuf) -> String {
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

#[test]
fn store_report_is_byte_identical_to_direct_scan() {
    let td = TempDir::new("store-rt").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    // A byte-identical copy at another path: a direct scan keeps it as
    // its own history point, so the store must too.
    std::fs::copy(
        input.join("exp/talp_2x2_run0.json"),
        input.join("exp/talp_2x2_run0_copy.json"),
    )
    .unwrap();
    let store = td.path().join("store");

    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            input.display(),
            store.display()
        ))
        .unwrap(),
        0
    );

    // Gate inline too, so the verdict triple is part of the identity
    // check (quiet policy: the fixture's histories improve, so the
    // verdict is a pass and the report exits 0).
    let policy = td.path().join("policy.json");
    std::fs::write(
        &policy,
        r#"{"version":1,"defaults":{"max_elapsed_increase":0.9}}"#,
    )
    .unwrap();
    let direct = td.path().join("site-direct");
    let stored = td.path().join("site-store");
    for (flag, src, out) in
        [("--input", &input, &direct), ("--store", &store, &stored)]
    {
        assert_eq!(
            run_cli(&format!(
                "report {flag} {} --output {} --format all --gate {}",
                src.display(),
                out.display(),
                policy.display()
            ))
            .unwrap(),
            0
        );
    }

    let d = read(direct.join("report.json"));
    let s = read(stored.join("report.json"));
    assert!(
        d.contains("\"kind\": \"improvement\""),
        "fixture must produce a detection, or identity is vacuous"
    );
    assert_eq!(d, s, "store-backed report.json differs from direct scan");
    // The gate triple is byte-identical too (path-free outputs).
    for f in ["gate.json", "gate.md", "gate.xml"] {
        assert_eq!(read(direct.join(f)), read(stored.join(f)), "{f}");
    }
    // And the HTML index renders the same experiment set.
    assert!(stored.join("index.html").exists());
}

#[test]
fn warm_reingest_parses_zero_artifacts() {
    let td = TempDir::new("store-warm").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    let mut store =
        RunStore::create_or_open(&td.path().join("store")).unwrap();

    let cold = ingest_dir(&mut store, &input).unwrap();
    assert_eq!(cold.scanned, 4);
    assert_eq!(cold.parsed, 4);
    assert_eq!(cold.stored, 4);

    let warm = ingest_dir(&mut store, &input).unwrap();
    assert_eq!(warm.scanned, 4);
    assert_eq!(warm.parsed, 0, "warm ingest must parse zero artifacts");
    assert_eq!(warm.stored, 0);
    assert_eq!(warm.already_stored, 4);

    // Adding one run re-parses exactly the new file.
    run(2, 14.0, 9.5, 3000, "third0003")
        .write_file(&input.join("exp/talp_2x2_run2.json"))
        .unwrap();
    let incr = ingest_dir(&mut store, &input).unwrap();
    assert_eq!(incr.parsed, 1);
    assert_eq!(incr.stored, 1);
    assert_eq!(store.len(), 5);
}

#[test]
fn corrupt_shard_record_warns_but_report_survives() {
    let td = TempDir::new("store-corrupt").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    let store = td.path().join("store");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            input.display(),
            store.display()
        ))
        .unwrap(),
        0
    );

    // Simulate a CI job killed mid-append: truncated trailing record.
    let shard = store.join("shards/exp__2x2.jsonl");
    assert!(shard.exists(), "expected shard layout shards/<exp>__<cfg>");
    let mut text = read(shard.clone());
    text.push_str("{\"hash\":\"zzz\",\"experiment\":\"exp\",\"run\":{");
    std::fs::write(&shard, text).unwrap();

    let reloaded = RunStore::open(&store).unwrap();
    assert_eq!(reloaded.len(), 4, "intact records must survive");
    assert_eq!(reloaded.warnings().len(), 1);
    assert!(reloaded.warnings()[0].to_string().contains("exp__2x2.jsonl"));
    assert_eq!(reloaded.warnings()[0].code, "TP012");

    // The report still emits, carrying the warning in its document.
    let out = td.path().join("site");
    assert_eq!(
        run_cli(&format!(
            "report --store {} --output {} --format json",
            store.display(),
            out.display()
        ))
        .unwrap(),
        0
    );
    let doc = read(out.join("report.json"));
    assert!(doc.contains("skipping corrupt record"), "{doc}");

    // Compaction heals the shard: clean reload, report drops the
    // warning.
    let mut healing = RunStore::open(&store).unwrap();
    healing.compact().unwrap();
    let healed = RunStore::open(&store).unwrap();
    assert!(healed.warnings().is_empty());
    assert_eq!(healed.len(), 4);
}

#[test]
fn unknown_store_version_is_rejected() {
    let td = TempDir::new("store-ver").unwrap();
    let input = td.path().join("talp");
    build_fixture(&input);
    let store = td.path().join("store");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            input.display(),
            store.display()
        ))
        .unwrap(),
        0
    );
    std::fs::write(store.join(MANIFEST_FILE_NAME), "{\"version\": 7}")
        .unwrap();

    // Reading rejects...
    let err = RunStore::open(&store).unwrap_err().to_string();
    assert!(err.contains('7'), "{err}");
    // ...report --store rejects...
    assert!(run_cli(&format!(
        "report --store {} --output {} --format json",
        store.display(),
        td.path().join("x").display()
    ))
    .is_err());
    // ...and a fresh ingest refuses to clobber the unknown store.
    assert!(run_cli(&format!(
        "ingest --input {} --store {}",
        input.display(),
        store.display()
    ))
    .is_err());
}
