//! Degraded-mode acceptance (ISSUE 10): a failing snapshot refresh
//! must not kill the server — it keeps serving the last good snapshot,
//! flags the condition on `/healthz` + `/statsz`, and recovers on the
//! next successful refresh because [`Monitor::refresh`] leaves its
//! dirty set intact on failure.
//!
//! Lives in its own test binary: the injected `serve::refresh` fault
//! is process-global state, and the other serve tests (which also
//! refresh) must never share a process with it.

#![cfg(feature = "failpoints")]

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use talp_pages::cli;
use talp_pages::gate::GatePolicy;
use talp_pages::serve::{self, ServeOptions};
use talp_pages::session::AnalyzeOptions;
use talp_pages::store::RunStore;
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::failpoint;
use talp_pages::util::fs::TempDir;

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

fn run(ranks: u32, useful: f64, elapsed: f64, ts: i64, sha: &str) -> RunData {
    RunData {
        dlb_version: "test".into(),
        app: "store-rt".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks,
        threads: 2,
        nodes: 1,
        regions: vec![RegionData {
            name: "Global".into(),
            elapsed_s: elapsed,
            visits: 1,
            procs: (0..ranks)
                .map(|r| ProcStats {
                    rank: r,
                    elapsed_s: elapsed,
                    useful_s: useful,
                    mpi_s: 0.05 * elapsed,
                    ..Default::default()
                })
                .collect(),
        }],
        git: Some(GitMeta {
            commit: sha.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

fn seeded_store(td: &TempDir) -> (PathBuf, PathBuf) {
    let input = td.path().join("talp");
    run(2, 24.0, 16.0, 1000, "slowslow1")
        .write_file(&input.join("exp/talp_2x2_run0.json"))
        .unwrap();
    run(2, 15.0, 10.0, 2000, "fastfast2")
        .write_file(&input.join("exp/talp_2x2_run1.json"))
        .unwrap();
    let store = td.path().join("store");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            input.display(),
            store.display()
        ))
        .unwrap(),
        0
    );
    let policy = td.path().join("policy.json");
    std::fs::write(
        &policy,
        r#"{"version":1,"defaults":{"max_elapsed_increase":0.9}}"#,
    )
    .unwrap();
    (store, policy)
}

fn serve_opts(store: &Path, policy: &Path) -> ServeOptions {
    let mut opts = ServeOptions::new(store);
    opts.addr = "127.0.0.1:0".to_string();
    opts.analyze = AnalyzeOptions {
        gate: Some(GatePolicy::from_file(policy).unwrap()),
        ..Default::default()
    };
    opts
}

fn request(
    addr: SocketAddr,
    method: &str,
    target: &str,
    body: &[u8],
) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    let head = format!(
        "{method} {target} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(body).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let pos = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .unwrap_or_else(|| panic!("no header end in {buf:?}"));
    let head = String::from_utf8_lossy(&buf[..pos]).into_owned();
    let status: u16 = head
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line: {head}"));
    (status, buf[pos + 4..].to_vec())
}

fn get_text(addr: SocketAddr, target: &str) -> (u16, String) {
    let (status, body) = request(addr, "GET", target, &[]);
    (status, String::from_utf8(body).unwrap())
}

#[test]
fn failed_refresh_keeps_last_good_snapshot_and_flags_degraded() {
    let td = TempDir::new("serve-degraded").unwrap();
    let (store, policy) = seeded_store(&td);
    let handle = serve::spawn(serve_opts(&store, &policy)).unwrap();
    let addr = handle.addr();

    let (status, before) = request(addr, "GET", "/report.json", &[]);
    assert_eq!(status, 200);
    let (_, health) = get_text(addr, "/healthz");
    assert!(health.contains("\"degraded\":false"), "{health}");

    // The NEXT refresh fails once (default rule: first consult after
    // configure), every later one succeeds.
    failpoint::configure("serve::refresh=enospc").unwrap();

    let fresh = run(2, 14.0, 9.5, 3000, "third0003")
        .to_json()
        .to_string_pretty();
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=exp/talp_2x2_run2.json",
        fresh.as_bytes(),
    );
    assert_eq!(status, 500, "{}", String::from_utf8_lossy(&body));

    // The run is stored but the snapshot could not be rebuilt: the old
    // one keeps being served, and the condition is flagged.
    let (status, after) = request(addr, "GET", "/report.json", &[]);
    assert_eq!(status, 200);
    assert_eq!(before, after, "degraded mode must serve the old bytes");
    let (_, health) = get_text(addr, "/healthz");
    assert!(health.contains("\"ok\":true"), "{health}");
    assert!(health.contains("\"degraded\":true"), "{health}");
    assert!(health.contains("\"snapshot_seq\":1"), "{health}");
    let (_, stats) = get_text(addr, "/statsz");
    assert!(stats.contains("\"degraded\":true"), "{stats}");
    assert!(stats.contains("\"refresh_failures\":1"), "{stats}");
    assert!(stats.contains("injected failure"), "{stats}");

    // Recovery: the failed refresh kept its dirty set, so the next
    // ingest retries the same experiments and clears the flag.
    let fresh2 = run(2, 13.5, 9.0, 4000, "fourth004")
        .to_json()
        .to_string_pretty();
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=exp/talp_2x2_run3.json",
        fresh2.as_bytes(),
    );
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let reply = String::from_utf8(body).unwrap();
    assert!(reply.contains("\"snapshot_seq\":2"), "{reply}");

    let (_, health) = get_text(addr, "/healthz");
    assert!(health.contains("\"degraded\":false"), "{health}");
    assert!(health.contains("\"snapshot_seq\":2"), "{health}");
    let (status, recovered) = request(addr, "GET", "/report.json", &[]);
    assert_eq!(status, 200);
    assert_ne!(
        before, recovered,
        "the recovered snapshot must include the retried experiments"
    );

    handle.shutdown().unwrap();
    // Both POSTed runs made it into the store — degraded mode loses
    // no data, only snapshot freshness.
    assert_eq!(RunStore::open(&store).unwrap().len(), 4);
}
