//! Acceptance tests for `talp-pages serve` (ISSUE 8):
//!
//! * every payload the server answers is byte-identical to the batch
//!   `report --store` output over the same corpus — before AND after
//!   a `POST /ingest`;
//! * concurrent readers during an ingest observe the old or the new
//!   snapshot, never a torn mix;
//! * malformed / oversize / unroutable POSTs get 4xx without touching
//!   the store or the snapshot;
//! * shutdown drains, releases the writer lock and leaves no torn
//!   shard behind; the watch directory flushes on the way out;
//! * a torn trailing shard line is tolerated exactly like batch mode.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};

use talp_pages::cli;
use talp_pages::gate::GatePolicy;
use talp_pages::serve::{self, ServeOptions};
use talp_pages::session::AnalyzeOptions;
use talp_pages::store::{RunStore, LOCK_FILE_NAME};
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;

fn run_cli(line: &str) -> anyhow::Result<i32> {
    cli::main_with_args(
        &line.split_whitespace().map(String::from).collect::<Vec<_>>(),
    )
}

/// Same hand-built fixture as the store_roundtrip tests: exact decimal
/// inputs, a 16 -> 10 elapsed drop so the documents carry detections.
fn run(ranks: u32, useful: f64, elapsed: f64, ts: i64, sha: &str) -> RunData {
    RunData {
        dlb_version: "test".into(),
        app: "store-rt".into(),
        machine: "mn5".into(),
        timestamp: ts,
        ranks,
        threads: 2,
        nodes: 1,
        regions: vec![RegionData {
            name: "Global".into(),
            elapsed_s: elapsed,
            visits: 1,
            procs: (0..ranks)
                .map(|r| ProcStats {
                    rank: r,
                    elapsed_s: elapsed,
                    useful_s: useful,
                    mpi_s: 0.05 * elapsed,
                    ..Default::default()
                })
                .collect(),
        }],
        git: Some(GitMeta {
            commit: sha.into(),
            branch: "main".into(),
            commit_timestamp: ts,
            message: String::new(),
        }),
    }
}

fn build_fixture(root: &Path) {
    run(2, 24.0, 16.0, 1000, "slowslow1")
        .write_file(&root.join("exp/talp_2x2_run0.json"))
        .unwrap();
    run(2, 15.0, 10.0, 2000, "fastfast2")
        .write_file(&root.join("exp/talp_2x2_run1.json"))
        .unwrap();
    run(4, 15.0, 10.0, 1000, "slowslow1")
        .write_file(&root.join("exp/talp_4x2_run0.json"))
        .unwrap();
    run(4, 15.0, 10.0, 2000, "fastfast2")
        .write_file(&root.join("exp/talp_4x2_run1.json"))
        .unwrap();
}

/// Ingest the fixture into a store and return (store, policy) paths.
fn seeded_store(td: &TempDir) -> (PathBuf, PathBuf) {
    let input = td.path().join("talp");
    build_fixture(&input);
    let store = td.path().join("store");
    assert_eq!(
        run_cli(&format!(
            "ingest --input {} --store {}",
            input.display(),
            store.display()
        ))
        .unwrap(),
        0
    );
    let policy = td.path().join("policy.json");
    std::fs::write(
        &policy,
        r#"{"version":1,"defaults":{"max_elapsed_increase":0.9}}"#,
    )
    .unwrap();
    (store, policy)
}

fn serve_opts(store: &Path, policy: &Path) -> ServeOptions {
    let mut opts = ServeOptions::new(store);
    opts.addr = "127.0.0.1:0".to_string();
    opts.analyze = AnalyzeOptions {
        gate: Some(GatePolicy::from_file(policy).unwrap()),
        ..Default::default()
    };
    opts
}

/// One raw HTTP/1.1 exchange (the server closes per request).
fn request(
    addr: SocketAddr,
    method: &str,
    target: &str,
    body: &[u8],
) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    let head = format!(
        "{method} {target} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    s.write_all(head.as_bytes()).unwrap();
    s.write_all(body).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let pos = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .unwrap_or_else(|| panic!("no header end in {buf:?}"));
    let head = String::from_utf8_lossy(&buf[..pos]).into_owned();
    let status: u16 = head
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line: {head}"));
    (status, buf[pos + 4..].to_vec())
}

fn get(addr: SocketAddr, target: &str) -> (u16, Vec<u8>) {
    request(addr, "GET", target, &[])
}

/// Like [`get`] but returns the raw response text (headers included),
/// for asserting on specific header lines.
fn raw_get(addr: SocketAddr, target: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        format!("GET {target} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes(),
    )
    .unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    String::from_utf8_lossy(&buf).into_owned()
}

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

/// Recursively collect (relative path, bytes) under `dir`.
fn walk(dir: &Path, prefix: &str, out: &mut Vec<(String, Vec<u8>)>) {
    for entry in std::fs::read_dir(dir).unwrap() {
        let entry = entry.unwrap();
        let name = entry.file_name().to_string_lossy().into_owned();
        let rel = if prefix.is_empty() {
            name
        } else {
            format!("{prefix}/{name}")
        };
        let p = entry.path();
        if p.is_dir() {
            walk(&p, &rel, out);
        } else {
            out.push((rel, std::fs::read(&p).unwrap()));
        }
    }
}

/// Batch-report the store and assert every produced file is served
/// byte-identically.  Returns the batch file list.
fn assert_serves_batch_output(
    addr: SocketAddr,
    store: &Path,
    policy: &Path,
    out: &Path,
) -> Vec<(String, Vec<u8>)> {
    // The gate verdict decides the exit code, not whether files are
    // written — identity is the assertion here.
    let code = run_cli(&format!(
        "report --store {} --output {} --format all --gate {}",
        store.display(),
        out.display(),
        policy.display()
    ))
    .unwrap();
    assert!(code == 0 || code == 1, "unexpected report exit {code}");
    let mut files = Vec::new();
    walk(out, "", &mut files);
    assert!(
        files.iter().any(|(n, _)| n == "report.json"),
        "batch produced no report.json"
    );
    assert!(files.iter().any(|(n, _)| n == "gate.json"));
    assert!(files.iter().any(|(n, _)| n.starts_with("badges/")));
    for (name, bytes) in &files {
        let (status, body) = get(addr, &format!("/{name}"));
        assert_eq!(status, 200, "GET /{name}");
        assert_eq!(
            &body, bytes,
            "served /{name} differs from the batch emitter output"
        );
    }
    // `/` is the site index.
    let (status, body) = get(addr, "/");
    assert_eq!(status, 200);
    let index = files.iter().find(|(n, _)| n == "index.html").unwrap();
    assert_eq!(body, index.1);
    files
}

#[test]
fn served_payloads_match_batch_before_and_after_ingest() {
    let td = TempDir::new("serve-identity").unwrap();
    let (store, policy) = seeded_store(&td);
    let handle = serve::spawn(serve_opts(&store, &policy)).unwrap();
    let addr = handle.addr();

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    let health = String::from_utf8(body).unwrap();
    assert!(health.contains("\"ok\":true"), "{health}");
    assert!(health.contains("\"snapshot_seq\":1"), "{health}");

    assert_serves_batch_output(addr, &store, &policy, &td.path().join("b1"));

    // Ingest one run over HTTP; the batch CLI sees the same store
    // mutation (read paths take no lock) and must still byte-match.
    let fresh = run(2, 14.0, 9.5, 3000, "third0003")
        .to_json()
        .to_string_pretty();
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=exp/talp_2x2_run2.json",
        fresh.as_bytes(),
    );
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let reply = String::from_utf8(body).unwrap();
    assert!(reply.contains("\"stored\":true"), "{reply}");
    assert!(reply.contains("\"snapshot_seq\":2"), "{reply}");

    // Incrementality witness: only the "exp" experiment re-analyzed —
    // its two (experiment, config) histories, nothing else.
    let (_, body) = get(addr, "/statsz");
    let stats = String::from_utf8(body).unwrap();
    assert!(
        stats.contains("\"reanalyzed_histories_last\":2"),
        "{stats}"
    );
    assert!(stats.contains("\"stored_runs\":5"), "{stats}");

    assert_serves_batch_output(addr, &store, &policy, &td.path().join("b2"));

    // Re-POSTing identical bytes is a content-addressed no-op.
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=exp/talp_2x2_run2.json",
        fresh.as_bytes(),
    );
    assert_eq!(status, 200);
    let reply = String::from_utf8(body).unwrap();
    assert!(reply.contains("\"stored\":false"), "{reply}");
    assert!(reply.contains("\"snapshot_seq\":2"), "{reply}");

    handle.shutdown().unwrap();
}

#[test]
fn foreign_format_posts_ingest_into_one_store() {
    let td = TempDir::new("serve-adapters").unwrap();
    let (store, policy) = seeded_store(&td);
    let handle = serve::spawn(serve_opts(&store, &policy)).unwrap();
    let addr = handle.addr();

    // ROOT-bench body, auto-detected.
    let bench = std::fs::read(fixture("root_bench.json")).unwrap();
    let (status, body) =
        request(addr, "POST", "/ingest?source=ci/bench.json", &bench);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let reply = String::from_utf8(body).unwrap();
    assert!(reply.contains("\"stored\":true"), "{reply}");
    assert!(reply.contains("\"format\":\"root-bench\""), "{reply}");
    assert!(reply.contains("\"runs\":1"), "{reply}");

    // A BeeSwarm scaling sweep, format pinned: one body, three runs.
    let sweep = std::fs::read(fixture("beeswarm.json")).unwrap();
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=ci/sweep.json&format=beeswarm",
        &sweep,
    );
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let reply = String::from_utf8(body).unwrap();
    assert!(reply.contains("\"stored\":true"), "{reply}");
    assert!(reply.contains("\"format\":\"beeswarm\""), "{reply}");
    assert!(reply.contains("\"runs\":3"), "{reply}");

    // An ambiguous body is a hard 400, never a guess.
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=ci/both.json",
        br#"{"scales": [], "context": {}, "benchmarks": []}"#,
    );
    assert_eq!(status, 400);
    assert!(String::from_utf8_lossy(&body).contains("ambiguous"));
    // Unknown pinned format: 400 naming the registry.
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=ci/x.json&format=protobuf",
        &bench,
    );
    assert_eq!(status, 400);
    assert!(
        String::from_utf8_lossy(&body).contains("talp|root-bench|beeswarm")
    );
    // Pinned to the wrong format: the parse fails, 400.
    let (status, _) = request(
        addr,
        "POST",
        "/ingest?source=ci/y.json&format=talp",
        &sweep,
    );
    assert_eq!(status, 400);

    // /statsz carries the per-format admission counters.
    let (_, body) = get(addr, "/statsz");
    let stats = String::from_utf8(body).unwrap();
    assert!(stats.contains("\"formats\":{"), "{stats}");
    assert!(stats.contains("\"beeswarm\":3"), "{stats}");
    assert!(stats.contains("\"root-bench\":1"), "{stats}");
    assert!(stats.contains("\"stored_runs\":8"), "{stats}");

    // Re-POSTing the sweep is warm at the file level: one hash check,
    // no parse, nothing stored.
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=ci/sweep.json&format=beeswarm",
        &sweep,
    );
    assert_eq!(status, 200);
    assert!(String::from_utf8(body).unwrap().contains("\"stored\":false"));

    handle.shutdown().unwrap();
    assert_eq!(RunStore::open(&store).unwrap().len(), 8);
}

#[test]
fn rejected_posts_do_not_poison_the_snapshot() {
    let td = TempDir::new("serve-reject").unwrap();
    let (store, policy) = seeded_store(&td);
    let mut opts = serve_opts(&store, &policy);
    opts.max_body_bytes = 1024;
    let handle = serve::spawn(opts).unwrap();
    let addr = handle.addr();
    let (_, before) = get(addr, "/report.json");

    // No source param.
    let (status, _) = request(addr, "POST", "/ingest", b"{}");
    assert_eq!(status, 400);
    // Path escape attempts.
    for bad in ["/etc/x.json", "../up.json", "a//b.json", "a/../b.json"] {
        let (status, _) = request(
            addr,
            "POST",
            &format!("/ingest?source={bad}"),
            b"{}",
        );
        assert_eq!(status, 400, "source={bad}");
    }
    // Empty body.
    let (status, _) =
        request(addr, "POST", "/ingest?source=exp/a.json", &[]);
    assert_eq!(status, 400);
    // Valid JSON that is not a TALP artifact.
    let (status, body) = request(
        addr,
        "POST",
        "/ingest?source=exp/a.json",
        b"{\"not\":\"talp\"}",
    );
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    // Unparsable bytes.
    let (status, _) =
        request(addr, "POST", "/ingest?source=exp/a.json", b"][");
    assert_eq!(status, 400);
    // Over the body cap.
    let (status, _) = request(
        addr,
        "POST",
        "/ingest?source=exp/a.json",
        &vec![b'x'; 4096],
    );
    assert_eq!(status, 413);
    // Companion metadata without a commit.
    let (status, _) = request(
        addr,
        "POST",
        "/ingest?source=exp/a.json&branch=main",
        b"{}",
    );
    assert_eq!(status, 400);
    // Bad timestamp.
    let (status, _) = request(
        addr,
        "POST",
        "/ingest?source=exp/a.json&commit=abc&timestamp=yesterday",
        b"{}",
    );
    assert_eq!(status, 400);
    // Unknown path and method.
    let (status, _) = get(addr, "/nope.json");
    assert_eq!(status, 404);
    let (status, _) = request(addr, "POST", "/report.json", b"{}");
    assert_eq!(status, 405);

    // Through all of that: same snapshot, same bytes, nothing stored.
    let (_, health) = get(addr, "/healthz");
    assert!(
        String::from_utf8(health).unwrap().contains("\"snapshot_seq\":1")
    );
    let (_, after) = get(addr, "/report.json");
    assert_eq!(before, after);
    let summary = handle.shutdown().unwrap();
    assert_eq!(summary.ingested, 0);
    assert!(summary.rejected >= 10, "{summary:?}");
    assert_eq!(RunStore::open(&store).unwrap().len(), 4);
}

#[test]
fn concurrent_readers_see_old_or_new_never_torn() {
    let td = TempDir::new("serve-race").unwrap();
    let (store, policy) = seeded_store(&td);
    let handle = serve::spawn(serve_opts(&store, &policy)).unwrap();
    let addr = handle.addr();

    let (_, old) = get(addr, "/report.json");
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(
        false,
    ));
    let reader_stop = std::sync::Arc::clone(&stop);
    let reader = std::thread::spawn(move || {
        let mut seen = Vec::new();
        while !reader_stop.load(std::sync::atomic::Ordering::SeqCst) {
            let (status, body) = get(addr, "/report.json");
            assert_eq!(status, 200);
            seen.push(body);
        }
        seen
    });

    for i in 0..3 {
        let fresh = run(2, 14.0 - i as f64, 9.0, 4000 + i as i64, "racerace")
            .to_json()
            .to_string_pretty();
        let (status, _) = request(
            addr,
            "POST",
            &format!("/ingest?source=exp/race_{i}.json"),
            fresh.as_bytes(),
        );
        assert_eq!(status, 200);
    }
    let (_, new) = get(addr, "/report.json");
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let seen = reader.join().unwrap();
    assert!(!seen.is_empty());

    // Every observed body must be one of the four complete snapshot
    // generations — never a mix.  Generations differ only by the three
    // ingests, so collect the valid set by replaying batch reports is
    // overkill: old and new bound the set; intermediate generations
    // are validated structurally (parseable, full document).
    for body in &seen {
        if body == &old || body == &new {
            continue;
        }
        let text = String::from_utf8(body.clone())
            .expect("served report.json is valid UTF-8");
        assert!(
            text.ends_with("}\n") || text.ends_with('}'),
            "torn response tail: ...{:?}",
            &text[text.len().saturating_sub(40)..]
        );
        talp_pages::util::json::Json::parse(&text)
            .expect("every served generation parses as a full document");
    }
    handle.shutdown().unwrap();
}

#[test]
fn shutdown_releases_lock_flushes_watch_and_leaves_no_torn_shard() {
    let td = TempDir::new("serve-shutdown").unwrap();
    let (store, policy) = seeded_store(&td);
    let watch = td.path().join("drop");
    std::fs::create_dir_all(&watch).unwrap();

    let mut opts = serve_opts(&store, &policy);
    opts.watch = Some(watch.clone());
    // Poll interval longer than the test: the shutdown flush is the
    // only way this artifact can make it in — which is the point.
    opts.poll_ms = 60_000;
    let handle = serve::spawn(opts).unwrap();
    let addr = handle.addr();

    // While running, the writer lock blocks a concurrent CLI ingest...
    assert!(store.join(LOCK_FILE_NAME).exists());
    let err = run_cli(&format!(
        "ingest --input {} --store {}",
        td.path().join("talp").display(),
        store.display()
    ))
    .unwrap_err();
    assert!(
        err.to_string().contains("locked by a running writer"),
        "{err:#}"
    );
    // ...but read-only batch reports work beside the server.
    assert!(run_cli(&format!(
        "report --store {} --output {} --format json",
        store.display(),
        td.path().join("beside").display()
    ))
    .is_ok());

    // Drop an artifact for the shutdown flush to pick up.
    run(2, 13.0, 8.5, 5000, "flushed00")
        .write_file(&watch.join("exp/talp_2x2_run9.json"))
        .unwrap();

    // Shutdown over HTTP, then wait for the clean exit.
    let (status, _) = request(addr, "POST", "/shutdown", &[]);
    assert_eq!(status, 200);
    let summary = handle.wait().unwrap();
    assert!(summary.ingested >= 1, "watch flush ingested: {summary:?}");

    // Lock released; no torn shard: a reload sees every record and no
    // corruption warnings; a new writer starts immediately.
    assert!(!store.join(LOCK_FILE_NAME).exists());
    let reloaded = RunStore::open(&store).unwrap();
    assert!(reloaded.warnings().is_empty(), "{:?}", reloaded.warnings());
    assert_eq!(reloaded.len(), 5, "4 seeded + 1 flushed");
    let second = serve::spawn(serve_opts(&store, &policy)).unwrap();
    second.shutdown().unwrap();
}

#[test]
fn slow_header_read_times_out_with_408() {
    let td = TempDir::new("serve-slowloris").unwrap();
    let (store, policy) = seeded_store(&td);
    let mut opts = serve_opts(&store, &policy);
    opts.read_timeout_ms = 200;
    let handle = serve::spawn(opts).unwrap();
    let addr = handle.addr();

    // A slowloris client: open the socket, send a header fragment,
    // then stall.  The per-connection read timeout must end it with a
    // 408 instead of pinning a handler thread forever.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /healthz HTTP/1.1\r\n").unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let head = String::from_utf8_lossy(&buf);
    assert!(head.starts_with("HTTP/1.1 408"), "{head}");

    // The listener survives the stalled client.
    let (status, _) = get(addr, "/healthz");
    assert_eq!(status, 200);
    handle.shutdown().unwrap();
}

#[test]
fn connection_cap_rejects_with_503_and_retry_after() {
    let td = TempDir::new("serve-cap").unwrap();
    let (store, policy) = seeded_store(&td);
    let mut opts = serve_opts(&store, &policy);
    opts.max_connections = 1;
    // Long enough that the held slot outlives the probe loop, short
    // enough that a bug cannot hang the test.
    opts.read_timeout_ms = 2_000;
    let handle = serve::spawn(opts).unwrap();
    let addr = handle.addr();

    // Occupy the only slot with a connection that never sends a byte,
    // then probe until the accept loop starts shedding load.  (The
    // first probe usually sees it already — accepts are FIFO — but the
    // cap is only observable once the held socket is accepted.)
    let slot = TcpStream::connect(addr).unwrap();
    let mut rejected = None;
    for _ in 0..200 {
        let text = raw_get(addr, "/healthz");
        if text.starts_with("HTTP/1.1 503") {
            rejected = Some(text);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let text = rejected.expect("cap never produced a 503");
    assert!(text.contains("Retry-After: 1"), "{text}");
    assert!(text.contains("connection cap"), "{text}");

    // Releasing the slot restores normal service.
    drop(slot);
    let mut recovered = false;
    for _ in 0..200 {
        if raw_get(addr, "/healthz").starts_with("HTTP/1.1 200") {
            recovered = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(recovered, "cap never released after the client hung up");
    let summary = handle.shutdown().unwrap();
    assert!(summary.rejected >= 1, "{summary:?}");
}

#[test]
fn torn_trailing_shard_line_tolerated_like_batch() {
    let td = TempDir::new("serve-torn").unwrap();
    let (store, policy) = seeded_store(&td);
    // Simulate a writer killed mid-append.
    let shard = store.join("shards/exp__2x2.jsonl");
    let mut text = std::fs::read_to_string(&shard).unwrap();
    text.push_str("{\"hash\":\"zzz\",\"experiment\":\"exp\",\"run\":{");
    std::fs::write(&shard, text).unwrap();

    let handle = serve::spawn(serve_opts(&store, &policy)).unwrap();
    let files = assert_serves_batch_output(
        handle.addr(),
        &store,
        &policy,
        &td.path().join("batch"),
    );
    let report = files.iter().find(|(n, _)| n == "report.json").unwrap();
    let doc = String::from_utf8(report.1.clone()).unwrap();
    assert!(doc.contains("skipping corrupt record"), "{doc}");
    handle.shutdown().unwrap();
}
