//! Property + corruption tests for the streaming JSON core.
//!
//! The tentpole invariant: the event pipe (`JsonReader` → `JsonWriter`)
//! reproduces the tree serializer (`Json::parse` + `to_string_*`)
//! byte-for-byte over arbitrary generated documents — so every hot
//! path that moved from the tree to the stream (store shards, metrics
//! cache, `report.json`) keeps emitting identical files.  Plus the
//! corruption ladder: truncation mid-escape and invalid UTF-8 degrade
//! to per-line warnings (shards) or a cold start (cache), never errors
//! or panics.

use talp_pages::pages::MetricsCache;
use talp_pages::pop::RunMetrics;
use talp_pages::store::RunStore;
use talp_pages::talp::{GitMeta, ProcStats, RegionData, RunData};
use talp_pages::util::fs::TempDir;
use talp_pages::util::json::{Json, JsonReader, JsonWriter};
use talp_pages::util::propcheck::check;
use talp_pages::util::rng::Rng;

// ---------- generator ----------

fn gen_string(rng: &mut Rng) -> String {
    let len = rng.below(12);
    (0..len)
        .map(|_| match rng.below(12) {
            0 => '"',
            1 => '\\',
            2 => '\n',
            3 => '\t',
            4 => '\u{1}', // forces a \u escape
            5 => '\u{263a}',
            6 => '\u{1f600}', // astral plane (4-byte UTF-8)
            7 => '/',
            _ => (b'a' + rng.below(26) as u8) as char,
        })
        .collect()
}

fn gen_num(rng: &mut Rng) -> Json {
    match rng.below(4) {
        0 => Json::Num(rng.below(1 << 50) as f64),
        1 => Json::Num(-(rng.below(100_000) as f64)),
        2 => Json::Num(rng.range_f64(-1e6, 1e6)),
        _ => Json::Num(rng.f64()),
    }
}

fn gen_json(rng: &mut Rng, depth: u32) -> Json {
    let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => gen_num(rng),
        3 => Json::Str(gen_string(rng)),
        4 => Json::Arr(
            (0..rng.below(5)).map(|_| gen_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| {
                    // Index suffix keeps keys unique within an object.
                    (format!("{}k{i}", gen_string(rng)), gen_json(rng, depth - 1))
                })
                .collect(),
        ),
    }
}

/// Replay `bytes` through the reader→writer event pipe.
fn pipe(bytes: &[u8], pretty: bool) -> Result<String, String> {
    let mut r = JsonReader::new(bytes);
    let mut w = JsonWriter::with_capacity(bytes.len(), pretty);
    loop {
        let ev = r.next().map_err(|e| e.to_string())?;
        w.event(&ev);
        if r.depth() == 0 {
            break;
        }
    }
    r.finish().map_err(|e| e.to_string())?;
    Ok(w.into_string())
}

// ---------- properties ----------

#[test]
fn event_pipe_reproduces_tree_serialization_byte_identically() {
    check("json stream roundtrip", 256, |rng| {
        let v = gen_json(rng, 4);

        let compact = v.to_string_compact();
        let piped = pipe(compact.as_bytes(), false)?;
        if piped != compact {
            return Err(format!(
                "compact pipe diverged:\n  in:  {compact}\n  out: {piped}"
            ));
        }

        // Pretty in, pretty out (modulo the trailing newline the tree
        // helper appends).
        let pretty = v.to_string_pretty();
        let piped = pipe(pretty.as_bytes(), true)? + "\n";
        if piped != pretty {
            return Err(format!(
                "pretty pipe diverged:\n  in:  {pretty}\n  out: {piped}"
            ));
        }

        // And the tree built from bytes equals the original value.
        let reparsed = Json::from_slice(compact.as_bytes())
            .map_err(|e| e.to_string())?;
        if reparsed != v {
            return Err(format!("from_slice diverged for {compact}"));
        }
        Ok(())
    });
}

#[test]
fn truncation_never_panics_and_never_parses() {
    // Chopping a valid document at any byte must yield a clean error
    // (or, for whitespace-only tails, possibly a valid prefix — JSON
    // scalars like numbers can be self-delimiting, so only check the
    // no-panic + deterministic behavior here).
    check("json stream truncation", 64, |rng| {
        let v = Json::Obj(vec![
            ("s".into(), Json::Str(gen_string(rng))),
            ("n".into(), gen_num(rng)),
            ("a".into(), gen_json(rng, 2)),
        ]);
        let text = v.to_string_compact();
        let cut = 1 + rng.below(text.len() as u64 - 1) as usize;
        let mut bytes = text.as_bytes()[..cut].to_vec();
        // Half the time, also flip the last byte to something invalid.
        if rng.below(2) == 0 {
            *bytes.last_mut().unwrap() = 0xff;
        }
        // Must not panic; an Err is expected (an object document cut
        // short can never be complete).
        if Json::from_slice(&bytes).is_ok() {
            return Err(format!(
                "truncated object parsed?! cut={cut} of {}",
                text.len()
            ));
        }
        Ok(())
    });
}

// ---------- RunData / RunMetrics codec equivalence ----------

fn sample_run(ranks: u32) -> RunData {
    RunData {
        dlb_version: "t".into(),
        app: "app \"quoted\" α".into(),
        machine: "mn5\n".into(),
        timestamp: 1_721_046_896,
        ranks,
        threads: 2,
        nodes: 1,
        regions: vec![RegionData {
            name: "Glob\tal".into(),
            elapsed_s: 1.25,
            visits: 3,
            procs: (0..ranks)
                .map(|r| ProcStats {
                    rank: r,
                    elapsed_s: 1.25,
                    useful_s: 1.0 / 3.0 + r as f64,
                    mpi_s: 0.125,
                    useful_instructions: 123_456_789,
                    useful_cycles: 987_654_321,
                    ..Default::default()
                })
                .collect(),
        }],
        git: Some(GitMeta {
            commit: "9dc04ca0".into(),
            branch: "main".into(),
            commit_timestamp: 1_721_000_000,
            message: "fix \\ escape".into(),
        }),
    }
}

#[test]
fn artifact_files_round_trip_byte_identically_through_both_codecs() {
    let td = TempDir::new("json-stream-artifact").unwrap();
    let path = td.path().join("exp/talp_2x2.json");
    let run = sample_run(2);
    run.write_file(&path).unwrap();
    // The streamed file is exactly the tree serialization.
    let written = std::fs::read_to_string(&path).unwrap();
    assert_eq!(written, run.to_json().to_string_pretty());
    // And both decoders agree on it.
    let a = RunData::read_file(&path).unwrap(); // from_slice inside
    let b = RunData::from_json(&Json::parse(&written).unwrap()).unwrap();
    assert_eq!(
        a.to_json().to_string_compact(),
        b.to_json().to_string_compact()
    );
}

// ---------- corruption: store shards ----------

#[test]
fn shard_corruption_degrades_to_warnings() {
    let td = TempDir::new("json-stream-store").unwrap();
    // Build a store of three runs (distinct content each) through the
    // public ingest path.
    let input = td.path().join("talp");
    for i in 0..3u8 {
        let mut run = sample_run(2);
        run.timestamp += i as i64;
        run.write_file(&input.join(format!("exp/run_{i}.json"))).unwrap();
    }
    let store_root = td.path().join("store");
    let mut store = RunStore::create_or_open(&store_root).unwrap();
    talp_pages::store::ingest_dir(&mut store, &input).unwrap();
    assert_eq!(store.len(), 3);
    drop(store);

    // Corrupt the shard: a line truncated mid-escape and a line with
    // invalid UTF-8, between intact records.
    let shards_dir = store_root.join("shards");
    let shard = std::fs::read_dir(&shards_dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .find(|p| p.extension().is_some())
        .unwrap();
    let good = std::fs::read(&shard).unwrap();
    let lines: Vec<&[u8]> =
        good.split(|&b| b == b'\n').filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), 3);
    let mut rebuilt: Vec<u8> = Vec::new();
    rebuilt.extend_from_slice(lines[0]);
    rebuilt.push(b'\n');
    // Truncated mid-escape (a killed writer inside a string escape).
    rebuilt.extend_from_slice(br#"{"hash":"h","experiment":"e\"#);
    rebuilt.push(b'\n');
    rebuilt.extend_from_slice(lines[1]);
    rebuilt.push(b'\n');
    // Invalid UTF-8 inside a string.
    rebuilt.extend_from_slice(b"{\"hash\":\"\xc3\x28\",\"experiment\":\"e\"}\n");
    rebuilt.extend_from_slice(lines[2]);
    rebuilt.push(b'\n');
    std::fs::write(&shard, rebuilt).unwrap();

    let back = RunStore::open(&store_root).unwrap();
    assert_eq!(back.len(), 3, "all intact records survive");
    assert_eq!(back.warnings().len(), 2, "{:?}", back.warnings());
    assert!(back.warnings()[0].to_string().contains("line 2"));
    assert!(back.warnings()[1].to_string().contains("line 4"));
}

// ---------- corruption: metrics cache ----------

#[test]
fn cache_corruption_degrades_to_cold_start() {
    let td = TempDir::new("json-stream-cache").unwrap();
    let path = td.path().join(".talp-cache.json");
    let mut cache = MetricsCache::new();
    cache.insert(
        "exp/a.json",
        "deadbeef",
        RunMetrics::from_run(&sample_run(2), "exp/a.json"),
    );
    cache.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert_eq!(MetricsCache::load(&path).len(), 1, "sanity: loads warm");

    // Truncate at every-ish offset: always a cold start, never a panic
    // or partial load of a half-written entry.
    for cut in [1, good.len() / 4, good.len() / 2, good.len() - 2] {
        std::fs::write(&path, &good[..cut]).unwrap();
        assert!(
            MetricsCache::load(&path).is_empty(),
            "cut at {cut} must cold-start"
        );
    }

    // Invalid UTF-8 inside the document: cold start.
    let mut bad = good.clone();
    let pos = bad.windows(8).position(|w| w == b"deadbeef").unwrap();
    bad[pos + 2] = 0xff;
    std::fs::write(&path, &bad).unwrap();
    assert!(MetricsCache::load(&path).is_empty());

    // Untouched bytes still load.
    std::fs::write(&path, &good).unwrap();
    assert_eq!(MetricsCache::load(&path).len(), 1);
}
