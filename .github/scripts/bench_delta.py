#!/usr/bin/env python3
"""Compare BENCH_JSON records and warn on elapsed regressions.

Usage:
    bench_delta.py [--baseline FILE] [--write-merged FILE] \\
                   <previous/bench.json> <current/bench.json>

Each file holds one JSON object per line as extracted from the bench
log (`BENCH_JSON {...}`).  Records pair up by their "bench" name —
every named record is compared, not just the first — and every numeric
key ending in `_s` is treated as an elapsed time.  A regression greater
than REGRESSION_THRESHOLD emits a GitHub Actions `::warning::`
annotation per bench/metric — this step dogfoods the talp-pages gate
idea on our own bench, but stays advisory: hosted-runner noise must not
turn the pipeline red, so the exit code is always 0.

`--baseline` names the committed seed file (benches/BENCH_hotpaths.json)
used when no previous-run artifact exists — the first run on a branch
still gets a comparison.  Zero/non-positive baseline values mean "no
measurement yet" and are skipped.

`--write-merged` writes baseline ∪ previous ∪ current (later wins) so
the uploaded artifact always carries every known bench record, even if
one bench was skipped or crashed in this particular run.
"""

import json
import sys

REGRESSION_THRESHOLD = 0.20  # warn when elapsed grows by more than 20%


def load(path):
    """Parse a bench.json file into {bench_name: record}.

    One corrupt line (truncated artifact) must not discard the rest.
    """
    records = {}
    try:
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError as e:
                    print(f"note: {path}:{lineno} is not valid "
                          f"BENCH_JSON ({e}) — line skipped")
                    continue
                name = rec.get("bench", "?")
                if name in records:
                    print(f"note: {path}:{lineno} repeats bench "
                          f"'{name}' — later record wins")
                records[name] = rec
    except OSError as e:
        print(f"note: cannot read {path}: {e}")
    return records


def compare(prev, curr):
    """Print the per-bench delta table; return the warning count."""
    warned = 0
    for name, cur_rec in sorted(curr.items()):
        prev_rec = prev.get(name)
        if prev_rec is None:
            print(f"{name}: new bench, no baseline")
            continue
        print(f"{name}:")
        compared = 0
        for key, cur_val in cur_rec.items():
            if not key.endswith("_s"):
                continue
            if not isinstance(cur_val, (int, float)):
                continue
            prev_val = prev_rec.get(key)
            if not isinstance(prev_val, (int, float)) or prev_val <= 0:
                # 0 = "no measurement yet" (the committed seed
                # baseline) — nothing to compare against.
                continue
            compared += 1
            ratio = cur_val / prev_val
            marker = ""
            if ratio > 1.0 + REGRESSION_THRESHOLD:
                marker = "  <-- regression"
                warned += 1
                print(
                    f"::warning title=bench regression::{name}.{key} "
                    f"elapsed grew {prev_val:.4f}s -> {cur_val:.4f}s "
                    f"({(ratio - 1.0) * 100.0:+.1f}%)"
                )
            print(
                f"  {key:<16} {prev_val:>10.4f}s -> {cur_val:>10.4f}s "
                f"({(ratio - 1.0) * 100.0:+6.1f}%){marker}"
            )
        if compared == 0:
            print("  (no comparable elapsed metrics yet)")
    for name in sorted(set(prev) - set(curr)):
        print(f"{name}: present in baseline but not in this run")
    return warned


def main(argv):
    args = list(argv[1:])
    baseline_path = None
    merged_path = None
    while args and args[0].startswith("--"):
        flag = args.pop(0)
        if flag == "--baseline" and args:
            baseline_path = args.pop(0)
        elif flag == "--write-merged" and args:
            merged_path = args.pop(0)
        else:
            print(__doc__)
            return 2
    if len(args) != 2:
        print(__doc__)
        return 2

    baseline = load(baseline_path) if baseline_path else {}
    prev, curr = load(args[0]), load(args[1])

    # The reference is the previous run when one exists, else the
    # committed seed baseline.
    reference = prev if prev else baseline
    if prev:
        print(f"comparing against previous run ({args[0]})")
    elif baseline:
        print(
            "note: no previous bench-json artifact (first run on this "
            f"branch?) — comparing against committed baseline "
            f"({baseline_path})"
        )

    warned = 0
    if not curr:
        print("note: no current bench record — nothing to compare")
    elif not reference:
        print("note: no baseline at all — skipping delta")
    else:
        warned = compare(reference, curr)
        if warned:
            print(f"{warned} elapsed metric(s) regressed > "
                  f"{REGRESSION_THRESHOLD:.0%} (advisory only)")
        else:
            print("no elapsed regression above threshold")

    if merged_path:
        merged = {}
        for source in (baseline, prev, curr):
            merged.update(source)
        # Drop the baseline's self-description record once real
        # records exist.
        if len(merged) > 1:
            merged.pop("_meta", None)
        with open(merged_path, "w", encoding="utf-8") as f:
            for name in sorted(merged):
                f.write(json.dumps(merged[name]) + "\n")
        print(f"merged {len(merged)} record(s) -> {merged_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
