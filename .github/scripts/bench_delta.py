#!/usr/bin/env python3
"""Compare BENCH_JSON records; warn or fail on elapsed regressions.

Usage:
    bench_delta.py [--baseline FILE] [--write-merged FILE]
                   [--mode advisory|gate] [--fail-threshold RATIO]
                   [--allowlist FILE]
                   <previous/bench.json> <current/bench.json>
    bench_delta.py --assert-measured FILE

Each file holds one JSON object per line as extracted from the bench
log (`BENCH_JSON {...}`).  Records pair up by their "bench" name —
every named record is compared, not just the first — and every numeric
key ending in `_s` is treated as an elapsed time.

Two thresholds, two behaviours:

* growth beyond WARN_THRESHOLD (20%) always emits a GitHub Actions
  `::warning::` annotation — advisory, hosted-runner noise never turns
  the pipeline red by itself;
* in `--mode gate` (pull requests), growth beyond `--fail-threshold`
  (default 35%) emits `::error::` and the script exits 1 — a genuine
  perf regression blocks the merge.  `--mode advisory` (schedules,
  pushes) keeps the old always-exit-0 behaviour.

`--allowlist` names a file of bench names or `bench.metric_s` entries
(one per line, `#` comments) exempt from gating — the escape hatch for
a reviewed, intentional regression.

`--baseline` names the committed seed file (benches/BENCH_hotpaths.json)
used when no previous-run artifact exists.  That fallback is now loud:
a `::notice::` says which reference is in use, and gating against *no*
reference at all is a `::warning::`, never a silent skip (forked PRs
cannot download artifacts — they still gate against the committed
baseline).  Zero/non-positive reference values mean "no measurement
yet" and are skipped.

`--write-merged` writes baseline ∪ previous ∪ current (later wins) so
the uploaded artifact always carries every known bench record, even if
one bench was skipped or crashed in this particular run.

`--assert-measured FILE` is a standalone mode: exit 1 unless every
record in FILE (the committed baseline) carries at least one positive
`_s` metric and no zero ones — the guard that keeps an all-zero
placeholder baseline from ever landing again.
"""

import json
import sys

WARN_THRESHOLD = 0.20  # annotate when elapsed grows by more than 20%
DEFAULT_FAIL_THRESHOLD = 0.35  # gate mode fails beyond this growth


def load(path):
    """Parse a bench.json file into {bench_name: record}.

    One corrupt line (truncated artifact) must not discard the rest.
    """
    records = {}
    try:
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError as e:
                    print(f"note: {path}:{lineno} is not valid "
                          f"BENCH_JSON ({e}) — line skipped")
                    continue
                name = rec.get("bench", "?")
                if name in records:
                    print(f"note: {path}:{lineno} repeats bench "
                          f"'{name}' — later record wins")
                records[name] = rec
    except OSError as e:
        print(f"note: cannot read {path}: {e}")
    return records


def load_allowlist(path):
    """Bench names / bench.metric entries exempt from gating."""
    entries = set()
    if not path:
        return entries
    try:
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.split("#", 1)[0].strip()
                if line:
                    entries.add(line)
    except OSError as e:
        print(f"note: cannot read allowlist {path}: {e}")
    return entries


def assert_measured(path):
    """Exit code for --assert-measured: every record needs real numbers."""
    records = load(path)
    records.pop("_meta", None)
    if not records:
        print(f"::error title=bench baseline::{path} holds no bench "
              f"records")
        return 1
    bad = []
    for name, rec in sorted(records.items()):
        metrics = [
            (k, v)
            for k, v in rec.items()
            if k.endswith("_s") and isinstance(v, (int, float))
        ]
        if not metrics:
            bad.append(f"{name}: no *_s elapsed metric")
        bad.extend(
            f"{name}.{k} = {v} (unmeasured)"
            for k, v in metrics
            if v <= 0
        )
    if bad:
        for b in bad:
            print(f"::error title=bench baseline unmeasured::{b}")
        print(f"{len(bad)} unmeasured metric(s) in {path} — record real "
              f"timings (cargo bench --bench perf_hotpaths) and commit "
              f"them")
        return 1
    print(f"{path}: all {len(records)} record(s) carry measured "
          f"elapsed metrics")
    return 0


def compare(prev, curr, mode, fail_threshold, allow):
    """Print the per-bench delta table; return (warned, failed) counts."""
    warned = 0
    failed = 0
    for name, cur_rec in sorted(curr.items()):
        prev_rec = prev.get(name)
        if prev_rec is None:
            print(f"{name}: new bench, no baseline")
            continue
        print(f"{name}:")
        compared = 0
        for key, cur_val in cur_rec.items():
            if not key.endswith("_s"):
                continue
            if not isinstance(cur_val, (int, float)):
                continue
            prev_val = prev_rec.get(key)
            if not isinstance(prev_val, (int, float)) or prev_val <= 0:
                # 0 = "no measurement yet" — nothing to compare against.
                continue
            compared += 1
            ratio = cur_val / prev_val
            marker = ""
            allowed = name in allow or f"{name}.{key}" in allow
            if mode == "gate" and ratio > 1.0 + fail_threshold:
                if allowed:
                    marker = "  <-- regression (allowlisted)"
                    print(
                        f"::notice title=bench allowlisted::{name}.{key} "
                        f"grew {(ratio - 1.0) * 100.0:+.1f}% but is "
                        f"allowlisted"
                    )
                else:
                    marker = "  <-- regression (gate)"
                    failed += 1
                    print(
                        f"::error title=bench regression::{name}.{key} "
                        f"elapsed grew {prev_val:.4f}s -> {cur_val:.4f}s "
                        f"({(ratio - 1.0) * 100.0:+.1f}%), past the "
                        f"{fail_threshold:.0%} gate"
                    )
            elif ratio > 1.0 + WARN_THRESHOLD:
                marker = "  <-- regression"
                warned += 1
                print(
                    f"::warning title=bench regression::{name}.{key} "
                    f"elapsed grew {prev_val:.4f}s -> {cur_val:.4f}s "
                    f"({(ratio - 1.0) * 100.0:+.1f}%)"
                )
            print(
                f"  {key:<16} {prev_val:>10.4f}s -> {cur_val:>10.4f}s "
                f"({(ratio - 1.0) * 100.0:+6.1f}%){marker}"
            )
        if compared == 0:
            print("  (no comparable elapsed metrics yet)")
    for name in sorted(set(prev) - set(curr)):
        print(f"{name}: present in baseline but not in this run")
    return warned, failed


def main(argv):
    args = list(argv[1:])
    baseline_path = None
    merged_path = None
    allowlist_path = None
    mode = "advisory"
    fail_threshold = DEFAULT_FAIL_THRESHOLD
    while args and args[0].startswith("--"):
        flag = args.pop(0)
        if flag == "--baseline" and args:
            baseline_path = args.pop(0)
        elif flag == "--write-merged" and args:
            merged_path = args.pop(0)
        elif flag == "--allowlist" and args:
            allowlist_path = args.pop(0)
        elif flag == "--mode" and args:
            mode = args.pop(0)
            if mode not in ("advisory", "gate"):
                print(f"unknown --mode '{mode}' (advisory|gate)")
                return 2
        elif flag == "--fail-threshold" and args:
            try:
                fail_threshold = float(args.pop(0))
            except ValueError:
                print("--fail-threshold must be a ratio like 0.35")
                return 2
        elif flag == "--assert-measured" and args:
            return assert_measured(args.pop(0))
        else:
            print(__doc__)
            return 2
    if len(args) != 2:
        print(__doc__)
        return 2

    baseline = load(baseline_path) if baseline_path else {}
    prev, curr = load(args[0]), load(args[1])
    allow = load_allowlist(allowlist_path)

    # The reference is the previous run when one exists, else the
    # committed seed baseline — and the fallback is loud, because a
    # silently skipped comparison looks exactly like a pass.
    reference = prev if prev else baseline
    if prev:
        print(f"comparing against previous run ({args[0]})")
    elif baseline:
        print(
            f"::notice title=bench baseline::no previous-run bench-json "
            f"artifact (first run on this branch, or a forked PR "
            f"without artifact access) — comparing against the "
            f"committed baseline ({baseline_path})"
        )
    elif mode == "gate":
        print(
            "::warning title=bench gate skipped::no previous-run "
            "artifact and no committed baseline — nothing to gate "
            "against"
        )

    warned = failed = 0
    if not curr:
        print("note: no current bench record — nothing to compare")
    elif not reference:
        print("note: no baseline at all — skipping delta")
    else:
        warned, failed = compare(reference, curr, mode, fail_threshold,
                                 allow)
        if failed:
            print(f"{failed} elapsed metric(s) regressed > "
                  f"{fail_threshold:.0%} — failing the gate")
        elif warned:
            print(f"{warned} elapsed metric(s) regressed > "
                  f"{WARN_THRESHOLD:.0%} (advisory)")
        else:
            print("no elapsed regression above threshold")

    if merged_path:
        merged = {}
        for source in (baseline, prev, curr):
            merged.update(source)
        # Drop the baseline's self-description record once real
        # records exist.
        if len(merged) > 1:
            merged.pop("_meta", None)
        with open(merged_path, "w", encoding="utf-8") as f:
            for name in sorted(merged):
                f.write(json.dumps(merged[name]) + "\n")
        print(f"merged {len(merged)} record(s) -> {merged_path}")
    return 1 if (mode == "gate" and failed) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
