#!/usr/bin/env python3
"""Compare two BENCH_JSON records and warn on elapsed regressions.

Usage: bench_delta.py <previous/bench.json> <current/bench.json>

Each file holds one JSON object per line as extracted from the bench
log (`BENCH_JSON {...}`).  Records pair up by their "bench" name; every
numeric key ending in `_s` is treated as an elapsed time and compared.
A regression greater than REGRESSION_THRESHOLD emits a GitHub Actions
`::warning::` annotation — this step dogfoods the talp-pages gate idea
on our own bench, but stays advisory: hosted-runner noise must not turn
the pipeline red, so the exit code is always 0.
"""

import json
import sys

REGRESSION_THRESHOLD = 0.20  # warn when elapsed grows by more than 20%


def load(path):
    """Parse a bench.json file into {bench_name: record}.

    One corrupt line (truncated artifact) must not discard the rest.
    """
    records = {}
    try:
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError as e:
                    print(f"note: {path}:{lineno} is not valid "
                          f"BENCH_JSON ({e}) — line skipped")
                    continue
                records[rec.get("bench", "?")] = rec
    except OSError as e:
        print(f"note: cannot read {path}: {e}")
    return records


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    prev, curr = load(argv[1]), load(argv[2])
    if not curr:
        print("note: no current bench record — nothing to compare")
        return 0
    if not prev:
        print(
            "note: no previous bench-json artifact (first run on this "
            "branch?) — skipping delta"
        )
        return 0

    warned = 0
    for name, cur_rec in sorted(curr.items()):
        prev_rec = prev.get(name)
        if prev_rec is None:
            print(f"{name}: new bench, no baseline")
            continue
        print(f"{name}:")
        for key, cur_val in cur_rec.items():
            if not key.endswith("_s"):
                continue
            if not isinstance(cur_val, (int, float)):
                continue
            prev_val = prev_rec.get(key)
            if not isinstance(prev_val, (int, float)) or prev_val <= 0:
                continue
            ratio = cur_val / prev_val
            marker = ""
            if ratio > 1.0 + REGRESSION_THRESHOLD:
                marker = "  <-- regression"
                warned += 1
                print(
                    f"::warning title=bench regression::{name}.{key} "
                    f"elapsed grew {prev_val:.4f}s -> {cur_val:.4f}s "
                    f"({(ratio - 1.0) * 100.0:+.1f}%)"
                )
            print(
                f"  {key:<16} {prev_val:>10.4f}s -> {cur_val:>10.4f}s "
                f"({(ratio - 1.0) * 100.0:+6.1f}%){marker}"
            )
    if warned:
        print(f"{warned} elapsed metric(s) regressed > "
              f"{REGRESSION_THRESHOLD:.0%} (advisory only)")
    else:
        print("no elapsed regression above threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
