//! End-to-end driver (DESIGN.md §6) — the full system on a real small
//! workload, proving all layers compose:
//!
//! * **L1/L2 real numerics**: loads the AOT-compiled Pallas CG
//!   artifacts via PJRT, validates them against the rust-native
//!   reference, and runs a *real distributed matvec* with halo exchange
//!   across coordinator-managed subdomains.
//! * **L3 coordinator**: simulates a 12-commit GENE-X development
//!   history on two machines; every commit triggers a CI pipeline
//!   (matrix performance jobs under TALP → metadata stamping → artifact
//!   accumulation → report regeneration → pages publish).
//! * **Headline metric**: detects the Fig. 7 serialization-bug fix from
//!   the published report data and prints the report-generation cost
//!   next to what the trace-based alternative would have needed.
//!
//! Run with: `make artifacts && cargo run --release --example ci_pipeline`

use talp_pages::apps::TeaLeaf;
use talp_pages::ci::{CiEngine, MatrixSpec, PipelineOptions, Repo};
use talp_pages::pages::{scan, timeseries};
use talp_pages::runtime::{calibrate, Registry};
use talp_pages::session::AnalyzeOptions;
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::tools::{self, ToolKind};
use talp_pages::util::fs::TempDir;
use talp_pages::util::stats::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // ---------- phase 1: real-kernel validation (PJRT) ----------
    println!("== phase 1: AOT artifact validation (PJRT CPU) ==");
    match Registry::open_default() {
        Some(reg) => {
            let cal = calibrate::run(&reg)?;
            println!(
                "platform {} | {} cg artifacts validated | max |x-x_ref| = {:.2e} | residual drop {:.1e}",
                cal.platform,
                cal.artifacts_validated,
                cal.max_abs_err,
                cal.residual_drop
            );
            anyhow::ensure!(cal.max_abs_err < 5e-3, "artifact numerics off");
        }
        None => println!(
            "  (skipped: no artifacts/ — run `make artifacts` for the real-\
             kernel phase)"
        ),
    }

    // ---------- phase 2: the CI loop ----------
    println!("\n== phase 2: 12-commit GENE-X CI history (Fig. 4 cycle) ==");
    let root = TempDir::new("ci-e2e")?;
    let n_commits = 12;
    let fix_at = 7;
    let repo = Repo::genex_history(n_commits, fix_at, 99, 1_700_000_000);
    let jobs = MatrixSpec {
        case: "salpha".into(),
        resolutions: vec![2],
        configurations: vec![
            ("1Nx2MPI".into(), 2, 14),
            ("2Nx4MPI".into(), 4, 14),
        ],
        machine_tags: vec!["mn5".into(), "raven".into()],
    }
    .expand();
    let opts = PipelineOptions {
        analyze: AnalyzeOptions {
            regions: vec!["initialize".into(), "timestep".into()],
            region_for_badge: Some("timestep".into()),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut engine = CiEngine::new(root.path())?;
    let mut total_report_s = 0.0;
    for commit in &repo.commits {
        let r = engine.run_pipeline(commit, &jobs, &opts)?;
        total_report_s += r.wall_time_s;
        println!(
            "  pipeline {:>2} {} jobs={} history={} pages-report: {} exps, {} pages",
            r.pipeline_id,
            r.commit_short,
            r.jobs_run,
            r.history_files,
            r.report.experiments,
            r.report.pages_written
        );
    }

    // ---------- phase 3: detect the fix from the published data ----------
    println!("\n== phase 3: regression/improvement detection (Fig. 7) ==");
    let work_dirs = talp_pages::util::fs::subdirs(&root.path().join("work"));
    let talp_dir = work_dirs.last().unwrap().join("talp");
    let scanres = scan(&talp_dir)?;
    // Fig. 5 layout: one experiment folder per (case, resolution,
    // machine); the two node configurations live inside as columns.
    anyhow::ensure!(
        scanres.experiments.len() == 2,
        "expected 2 experiments (one per machine), got {}",
        scanres.experiments.len()
    );
    let mut detected = 0;
    for exp in &scanres.experiments {
        for cfg in exp.configs() {
            let history = exp.history_for_config(&cfg);
            if history.len() < n_commits {
                continue;
            }
            let ts = timeseries::build(&cfg, &history, &[]);
            let el = ts.metric("initialize", "elapsed");
            let ser =
                ts.metric("initialize", "omp_serialization_efficiency");
            // Find the largest improvement step.
            let (step, drop) = (1..el.len())
                .map(|i| (i, el[i - 1].1 / el[i].1.max(1e-12)))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            let ser_jump = ser[step].1 - ser[step - 1].1;
            let hit = step == fix_at && drop > 1.3 && ser_jump > 0.15;
            println!(
                "  {} {}: biggest step at commit #{step} (x{drop:.2} faster, \
                 serialization eff {:+.2}) {}",
                exp.id,
                cfg,
                ser_jump,
                if hit { "<- FIX DETECTED + EXPLAINED" } else { "" }
            );
            if hit {
                detected += 1;
            }
        }
    }
    anyhow::ensure!(
        detected >= 3,
        "fix detected in only {detected} experiment/config series"
    );

    // ---------- phase 4: headline cost comparison ----------
    println!("\n== phase 4: TALP-Pages vs trace-based alternative ==");
    let json_bytes = talp_pages::util::fs::dir_size(&talp_dir);
    println!(
        "  TALP-Pages: {} of JSON history for {} pipelines; total report \
         generation {:.2}s",
        fmt_bytes(json_bytes),
        n_commits,
        total_report_s
    );
    // What ONE pipeline's data would cost with the BSC trace chain:
    let td = TempDir::new("bsc-alt")?;
    let mut alt = TeaLeaf::with_grid(1024, 1024);
    alt.timesteps = 2;
    alt.cg_iters = 10;
    alt.write_output = false;
    let machine = MachineSpec::marenostrum5();
    let run = tools::instrument(
        ToolKind::ExtraeBsc,
        &alt,
        &machine,
        &ResourceConfig::new(2, 14),
        1,
        0,
        td.path(),
    )?;
    let (_, usage) = tools::postprocess(ToolKind::ExtraeBsc, &[&run], "Global")?;
    println!(
        "  BSC trace chain, ONE run of a smaller case: {} trace on disk, \
         post-processing {}",
        fmt_bytes(run.output_bytes),
        usage.summary()
    );
    println!(
        "  -> ratio (trace bytes per run / json bytes per run): ~{}x",
        run.output_bytes / (json_bytes / (n_commits as u64 * 4 * 2)).max(1)
    );
    println!(
        "\nE2E OK: real kernel validated, CI loop closed, fix detected and \
         explained, cost gap reproduced."
    );
    Ok(())
}
