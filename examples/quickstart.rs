//! Quickstart: the paper's standalone (non-CI) workflow in ~60 lines.
//!
//! 1. Run the TeaLeaf CG mini-app under TALP at two resource
//!    configurations (a strong-scaling experiment).
//! 2. Organize the TALP JSONs into the Fig. 2 folder structure.
//! 3. Point the staged Session pipeline at the folder and get the HTML
//!    report, scaling-efficiency table and badges.
//!
//! Run with: `cargo run --release --example quickstart`

use talp_pages::apps::{run_with_talp, TeaLeaf};
use talp_pages::pages;
use talp_pages::pop;
use talp_pages::session::{self, AnalyzeOptions, Session};
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::util::timefmt;

fn main() -> anyhow::Result<()> {
    let out_root = std::env::temp_dir().join("talp-pages-quickstart");
    let talp_folder = out_root.join("talp_folder/mesh_1/strong_scaling");
    let report_dir = out_root.join("report");
    let _ = std::fs::remove_dir_all(&out_root);

    // 1. Performance runs (simulated MareNostrum 5; numerics of the CG
    //    kernel are validated against the real AOT artifact — see the
    //    ci_pipeline example and runtime::calibrate).
    let machine = MachineSpec::marenostrum5();
    let mut app = TeaLeaf::with_grid(2000, 2000);
    app.timesteps = 2;
    app.cg_iters = 25;
    for (i, cfg) in [ResourceConfig::new(2, 28), ResourceConfig::new(4, 28)]
        .iter()
        .enumerate()
    {
        let (data, summary) = run_with_talp(
            &app,
            &machine,
            cfg,
            42 + i as u64,
            timefmt::now_unix(),
        );
        // 2. Fig. 2 folder structure.
        let path = talp_folder.join(format!("talp_{}.json", cfg.label()));
        data.write_file(&path)?;
        println!(
            "ran tealeaf {}: simulated elapsed {:.3}s -> {}",
            cfg.label(),
            summary.elapsed_s,
            path.display()
        );
    }

    // 3. Report generation (`talp-pages report -i talp_folder -o report`):
    //    scan -> analyze -> emit the full site + report.json.
    let summary = Session::new(out_root.join("talp_folder"))
        .scan()?
        .analyze(&AnalyzeOptions::default())
        .emit(&mut session::default_emitters(&report_dir))?;
    println!(
        "\nreport: {} experiment(s), {} page(s), {} badge(s)\nopen {}",
        summary.experiments,
        summary.pages_written,
        summary.badges_written,
        report_dir.join("index.html").display()
    );

    // Bonus: print the scaling-efficiency table the report contains.
    let scan = pages::scan(&out_root.join("talp_folder"))?;
    let table = pop::build("Global", &scan.experiments[0].latest_per_config())
        .expect("table");
    println!("\n{}", table.render_text());
    println!(
        "Note: TeaLeaf writes its output serially on rank 0 and TALP is\n\
         blind to I/O (paper §Discussion) — that skew is what depresses\n\
         MPI load balance here.  Set `app.write_output = false` (or\n\
         instrument the I/O region with the TALP API) to see it vanish."
    );
    Ok(())
}
