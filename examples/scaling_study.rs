//! Scaling study: the three experiment types TALP-Pages supports in one
//! Fig. 2 folder — a strong-scaling experiment, a weak-scaling
//! experiment and a resource-configuration comparison — with automatic
//! scaling-mode detection, plus the MPI-only Fig. 3 case.
//!
//! `cargo run --release --example scaling_study`

use talp_pages::apps::{run_with_talp, MpiStencil, TeaLeaf};
use talp_pages::pages;
use talp_pages::pop;
use talp_pages::session::{self, AnalyzeOptions, Session};
use talp_pages::sim::{MachineSpec, ResourceConfig};

fn tealeaf(grid: u64) -> TeaLeaf {
    let mut t = TeaLeaf::with_grid(grid, grid);
    t.timesteps = 2;
    t.cg_iters = 20;
    t.write_output = false;
    t
}

fn main() -> anyhow::Result<()> {
    let machine = MachineSpec::marenostrum5();
    let root = std::env::temp_dir().join("talp-pages-scaling-study");
    let _ = std::fs::remove_dir_all(&root);
    let folder = root.join("talp_folder");

    // mesh_1/strong_scaling: fixed 4000^2, 2x56 -> 4x56.
    for cfg in [ResourceConfig::new(2, 56), ResourceConfig::new(4, 56)] {
        let (d, _) = run_with_talp(&tealeaf(4000), &machine, &cfg, 1, 0);
        d.write_file(
            &folder.join(format!(
                "mesh_1/strong_scaling/talp_{}.json",
                cfg.label()
            )),
        )?;
    }
    // mesh_1/weak_scaling: 4000^2@2x56 -> 8000^2@8x56.
    for (grid, cfg) in [
        (4000, ResourceConfig::new(2, 56)),
        (8000, ResourceConfig::new(8, 56)),
    ] {
        let (d, _) = run_with_talp(&tealeaf(grid), &machine, &cfg, 2, 0);
        d.write_file(
            &folder.join(format!(
                "mesh_1/weak_scaling/talp_{}.json",
                cfg.label()
            )),
        )?;
    }
    // mesh_1/comparison: same cpu budget, different rank/thread splits.
    for cfg in [
        ResourceConfig::new(1, 112),
        ResourceConfig::new(2, 56),
        ResourceConfig::new(4, 28),
    ] {
        let (d, _) = run_with_talp(&tealeaf(4000), &machine, &cfg, 3, 0);
        d.write_file(
            &folder.join(format!(
                "mesh_1/comparison/talp_{}.json",
                cfg.label()
            )),
        )?;
    }
    // mpi_only/fig3: 112 -> 224 single-thread ranks.
    let fig3 = MpiStencil::fig3();
    for cfg in [ResourceConfig::new(112, 1), ResourceConfig::new(224, 1)] {
        let (d, _) = run_with_talp(&fig3, &machine, &cfg, 4, 0);
        d.write_file(
            &folder.join(format!("mpi_only/fig3/talp_{}.json", cfg.label())),
        )?;
    }

    // Tables + detected modes.
    let scan = pages::scan(&folder)?;
    for exp in &scan.experiments {
        let table =
            pop::build("Global", &exp.latest_per_config()).expect("table");
        println!("# {}  (detected: {} scaling)", exp.id, table.mode.name());
        print!("{}", table.render_text());
        println!();
    }

    // And the full report for browsing.
    let out = root.join("report");
    let summary = Session::new(&folder)
        .scan()?
        .analyze(&AnalyzeOptions::default())
        .emit(&mut session::default_emitters(&out))?;
    println!(
        "report: {} experiments -> {}",
        summary.experiments,
        out.join("index.html").display()
    );
    Ok(())
}
