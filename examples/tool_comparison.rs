//! Tool comparison (paper §Comparison to other tools): run the four
//! chains on the TeaLeaf CG benchmark and print Table-1-style overheads
//! and Table-2-style post-processing requirements, plus each chain's
//! scaling-efficiency table.
//!
//! This is the CLI's `compare` subcommand as a library example:
//! `cargo run --release --example tool_comparison`

use talp_pages::apps::TeaLeaf;
use talp_pages::sim::{MachineSpec, ResourceConfig};
use talp_pages::tools::{self, InstrumentedRun, ToolKind};
use talp_pages::util::bench::Table;
use talp_pages::util::fs::TempDir;
use talp_pages::util::stats::{fmt_bytes, fmt_duration};

fn main() -> anyhow::Result<()> {
    let machine = MachineSpec::marenostrum5();
    let mut app = TeaLeaf::with_grid(2000, 2000);
    app.timesteps = 2;
    app.cg_iters = 15;
    app.write_output = false;
    let configs = [ResourceConfig::new(2, 28), ResourceConfig::new(4, 28)];
    let work = TempDir::new("toolcmp")?;

    let mut t1 = Table::new(
        "Runtime overhead (Table 1 shape)",
        &["tool", "config", "clean [s]", "instrumented [s]", "overhead",
          "app runs", "raw output"],
    );
    let mut t2 = Table::new(
        "Post-processing to the scaling table (Table 2 shape)",
        &["tool", "memory", "storage", "time"],
    );

    for kind in ToolKind::all() {
        let mut runs: Vec<InstrumentedRun> = Vec::new();
        for cfg in &configs {
            let dir = work.path().join(kind.short()).join(cfg.label());
            let run =
                tools::instrument(kind, &app, &machine, cfg, 42, 0, &dir)?;
            t1.row(&[
                kind.name().to_string(),
                cfg.label(),
                format!("{:.3}", run.clean_elapsed_s),
                format!("{:.3}", run.elapsed_s),
                format!("{:.1}%", run.overhead_fraction() * 100.0),
                run.app_runs.to_string(),
                fmt_bytes(run.output_bytes),
            ]);
            runs.push(run);
        }
        let refs: Vec<&InstrumentedRun> = runs.iter().collect();
        let (table, usage) = tools::postprocess(kind, &refs, "Global")?;
        t2.row(&[
            kind.name().to_string(),
            fmt_bytes(usage.peak_memory_bytes),
            fmt_bytes(usage.storage_bytes),
            fmt_duration(usage.wall_time_s),
        ]);
        if let Some(table) = table {
            println!("--- {} ---", kind.name());
            print!("{}", table.render_text());
            println!();
        }
    }
    t1.print();
    println!();
    t2.print();
    println!(
        "\nExpected shape: CPT ~ Score-P < DLB < Extrae in overhead;\n\
         TALP orders of magnitude below both trace chains in post-\n\
         processing; Score-P needed two app runs (POP preset)."
    );
    Ok(())
}
