"""AOT path tests: lowering produces PJRT-loadable HLO text with the
right entry signatures, and manifest metadata is consistent."""

import json
import os

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def entries():
    return list(aot.build_entries())


def test_all_expected_entries_present(entries):
    names = [n for n, _, _ in entries]
    assert "cg_solve_64x64_i30" in names
    assert "matvec_halo_128x128" in names
    assert "genex_step_128x128_s4" in names
    assert len(names) == len(set(names)), "duplicate artifact names"


def test_hlo_text_is_pjrt_compatible(entries):
    """interpret=True must lower the Pallas kernel into plain HLO ops —
    a Mosaic custom-call would be unloadable on the CPU PJRT client."""
    for name, lowered, meta in entries:
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "custom-call" not in text.lower(), (
            f"{name}: pallas did not lower to plain HLO"
        )
        # Tuple return (the rust side unpacks with to_tuple()).
        assert "ROOT" in text
        assert len(text) < 200_000, f"{name}: HLO blew up ({len(text)})"


def test_manifest_flops_match_model_formulas(entries):
    for name, _, meta in entries:
        expected = model.flops(meta["entry"], meta["h"], meta["w"],
                               meta["iters"])
        assert meta["flops"] == expected, name


def test_scan_keeps_hlo_compact(entries):
    """cg_solve uses lax.scan: its HLO must not scale with iteration
    count (the L2 §Perf claim)."""
    texts = {n: aot.to_hlo_text(l) for n, l, _ in entries
             if n.startswith("cg_solve")}
    sizes = sorted(len(t) for t in texts.values())
    # All cg_solve shapes lower to ~the same module size.
    assert sizes[-1] < 1.5 * sizes[0], sizes


def test_written_manifest_matches(tmp_path):
    """End-to-end of the aot CLI main()."""
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text-v1"
    for name, meta in manifest["artifacts"].items():
        f = tmp_path / meta["file"]
        assert f.exists(), name
        assert os.path.getsize(f) == meta["hlo_bytes"]


def test_perf_report_prints(capsys):
    print(aot.perf_report())
    out = capsys.readouterr().out
    assert "VMEM" in out
    assert "HBM-bw" in out
