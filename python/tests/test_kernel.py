"""Pallas stencil kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes/dtypes per the repro contract; fixed-shape tests
cover the AOT shapes exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stencil

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=dtype)


def random_problem(seed, h, w, dtype=jnp.float32):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = rand(k[0], h, w, dtype=dtype)
    kx = jax.random.uniform(k[1], (h, w + 1), dtype=dtype, minval=0.1,
                            maxval=1.0)
    ky = jax.random.uniform(k[2], (h, w), dtype=dtype, minval=0.1,
                            maxval=1.0)
    d = jax.random.uniform(k[3], (h, w), dtype=dtype, minval=1.0,
                           maxval=4.0)
    return p, kx, ky, d


@pytest.mark.parametrize("h,w", [(64, 64), (128, 128), (256, 256),
                                 (64, 128), (128, 64)])
def test_kernel_matches_ref_fixed_shapes(h, w):
    p, kx, ky, d = random_problem(0, h, w)
    got = stencil.apply_operator(p, kx, ky, d)
    want = ref.apply_operator_ref(p, kx, ky, d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    hb=st.integers(1, 6),
    w=st.integers(3, 130),
    block=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    # NOTE: x64 stays disabled in this image (AOT artifacts are f32);
    # bfloat16 exercises the low-precision path the TPU story relies on.
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_kernel_matches_ref_hypothesis(hb, w, block, seed, dtype):
    h = hb * block
    p, kx, ky, d = random_problem(seed % 1000, h, w, dtype=dtype)
    got = stencil.apply_operator(p, kx, ky, d, block=block)
    want = ref.apply_operator_ref(p, kx, ky, d)
    if dtype == jnp.bfloat16:
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=0.1, atol=0.1)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_kernel_rejects_unaligned_height():
    p, kx, ky, d = random_problem(0, 65, 64)
    with pytest.raises(ValueError, match="not a multiple"):
        stencil.apply_operator(p, kx, ky, d, block=64)


def test_halo_variant_equals_fused_domain():
    """Two stacked subdomains with exchanged halos == one fused domain."""
    h, w = 128, 96
    p, kxf, kyf, df = random_problem(3, h, w)
    full = ref.apply_operator_ref(p, kxf, kyf, df)

    top, bot = p[: h // 2], p[h // 2:]
    # rank-local coefficient slices
    sl = lambda a: (a[: h // 2], a[h // 2:])
    kx_t, kx_b = sl(kxf)
    ky_t, ky_b = sl(kyf)
    d_t, d_b = sl(df)
    zero = jnp.zeros((w,), p.dtype)

    got_top = stencil.apply_operator_halo(top, zero, bot[0], kx_t, ky_t,
                                          ky_b[0], d_t, block=16)
    got_bot = stencil.apply_operator_halo(bot, top[-1], zero, kx_b, ky_b,
                                          zero, d_b, block=16)
    # NOTE: the split operator differs from the fused one at the interface
    # row only through the ky face owned by the *lower* rank; TeaLeaf-style
    # decomposition keeps face arrays global, which our slices do.
    np.testing.assert_allclose(got_top, full[: h // 2], rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(got_bot, full[h // 2:], rtol=1e-5,
                               atol=1e-5)


def test_operator_is_symmetric_positive_definite():
    """CG's contract: <Ap, q> == <p, Aq> and <p, Ap> > 0 for coefficients
    from build_coefficients (zero-flux faces)."""
    h = w = 32
    kx, ky, d = ref.build_coefficients(h, w)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    p = rand(k1, h, w)
    q = rand(k2, h, w)
    ap = stencil.apply_operator(p, kx, ky, d, block=8)
    aq = stencil.apply_operator(q, kx, ky, d, block=8)
    assert abs(float(jnp.vdot(ap, q) - jnp.vdot(p, aq))) < 1e-2
    assert float(jnp.vdot(p, ap)) > 0


def test_flops_counts_match_kernel_definition():
    assert stencil.flops_per_application(10, 20) == 9 * 200
    assert stencil.vmem_bytes(64, 4096) < 16 * 2**20
