"""L2 model tests: CG convergence, scan-vs-loop equivalence, genex step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("h,w", [(64, 64), (128, 128)])
def test_cg_solve_matches_reference(h, w):
    kx, ky, d = ref.build_coefficients(h, w)
    b = model.initial_condition(h, w)
    x, hist = model.cg_solve(b, kx, ky, d, n_iters=20, block=16)
    x_ref, hist_ref = ref.cg_solve_ref(b, kx, ky, d, 20)
    np.testing.assert_allclose(x, x_ref, rtol=2e-3, atol=2e-4)
    # Converged-tail residuals are floating noise (~1e-12 of rr0); give
    # the comparison an absolute floor scaled by the initial residual.
    np.testing.assert_allclose(hist, hist_ref, rtol=2e-2,
                               atol=1e-9 * float(hist_ref[0]))


def test_cg_converges():
    h = w = 64
    kx, ky, d = ref.build_coefficients(h, w)
    b = model.initial_condition(h, w)
    x, hist = model.cg_solve(b, kx, ky, d, n_iters=40, block=16)
    # Residual must drop by orders of magnitude and the solution must
    # actually satisfy A x ~= b.
    assert float(hist[-1]) < 1e-6 * float(hist[0])
    ax = ref.apply_operator_ref(x, kx, ky, d)
    rel = float(jnp.linalg.norm(ax - b) / jnp.linalg.norm(b))
    assert rel < 1e-3


def test_residual_history_monotone_tail():
    """CG on an SPD operator: the energy norm decreases; the l2 residual
    can wiggle, but the tail (last 10 of 40) must be far below the head."""
    h = w = 64
    kx, ky, d = ref.build_coefficients(h, w)
    b = model.initial_condition(h, w)
    _, hist = model.cg_solve(b, kx, ky, d, n_iters=40, block=16)
    assert float(jnp.max(hist[-10:])) < float(jnp.min(hist[:3]))


def test_genex_step_stable_and_deterministic():
    h = w = 128
    kx, ky, d = ref.build_coefficients(h, w)
    u0 = model.initial_condition(h, w)
    u1, norms1 = model.genex_step(u0, kx, ky, d, n_sweeps=4, block=16)
    u2, norms2 = model.genex_step(u0, kx, ky, d, n_sweeps=4, block=16)
    np.testing.assert_array_equal(u1, u2)
    assert np.all(np.isfinite(np.asarray(u1)))
    # Diffusion + bounded nonlinearity: norm can't blow up.
    assert float(norms1[-1]) < 4.0 * float(jnp.vdot(u0, u0))


def test_initial_condition_matches_rust_formula():
    """Spot-check values the rust generator reproduces bit-compatibly-ish."""
    u = np.asarray(model.initial_condition(8, 8))
    i, j = 3, 5
    expected = (np.sin(np.pi * i / 8) * np.sin(np.pi * j / 8)
                + 0.1 * np.sin(9.0 * (i / 8) * (j / 8)))
    assert abs(u[i, j] - expected) < 1e-5


def test_flops_positive_and_scaling():
    f1 = model.flops("cg_solve", 64, 64, 30)
    f2 = model.flops("cg_solve", 128, 128, 30)
    assert f1 > 0 and 3.8 < f2 / f1 < 4.2
    with pytest.raises(ValueError):
        model.flops("nope", 1, 1, 1)
