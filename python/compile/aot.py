"""AOT entry: lower the L2 graphs to HLO *text* + write a manifest.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Outputs (in --out-dir, default ../artifacts):

    <entry>_<H>x<W>[_i<ITERS>].hlo.txt   one per (entry, shape)
    manifest.json                        name -> file, shapes, arg order,
                                         flops, vmem estimate

The rust ``runtime::registry`` reads manifest.json to discover
executables; ``sim::counters`` seeds its work model from the flop counts.

Run ``python -m compile.aot --report`` for the L1 static perf analysis
(VMEM footprint + arithmetic intensity per block shape, DESIGN.md §9).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import stencil

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# (entry name, shapes (H, W), static params)
CG_SHAPES = [(64, 64), (128, 128), (256, 256)]
CG_ITERS = 30
MATVEC_SHAPES = [(64, 64), (128, 128), (256, 256)]
GENEX_SHAPES = [(128, 128)]
GENEX_SWEEPS = 4


def build_entries():
    """Yield (artifact_name, lowered, meta) for every artifact."""
    for h, w in CG_SHAPES:
        name = f"cg_solve_{h}x{w}_i{CG_ITERS}"
        lowered = jax.jit(
            lambda b, kx, ky, d: model.cg_solve(b, kx, ky, d,
                                                n_iters=CG_ITERS)
        ).lower(spec(h, w), spec(h, w + 1), spec(h, w), spec(h, w))
        yield name, lowered, {
            "entry": "cg_solve", "h": h, "w": w, "iters": CG_ITERS,
            "args": ["b[h,w]", "kx[h,w+1]", "ky[h,w]", "d[h,w]"],
            "outputs": ["x[h,w]", "rr_hist[iters]"],
            "flops": model.flops("cg_solve", h, w, CG_ITERS),
        }
    for h, w in MATVEC_SHAPES:
        name = f"matvec_halo_{h}x{w}"
        lowered = jax.jit(
            lambda p, n, s, kx, ky, kyb, d: model.matvec_halo(
                p, n, s, kx, ky, kyb, d)
        ).lower(spec(h, w), spec(w), spec(w),
                spec(h, w + 1), spec(h, w), spec(w), spec(h, w))
        yield name, lowered, {
            "entry": "matvec_halo", "h": h, "w": w, "iters": 1,
            "args": ["p[h,w]", "north[w]", "south[w]",
                     "kx[h,w+1]", "ky[h,w]", "ky_bottom[w]", "d[h,w]"],
            "outputs": ["ap[h,w]"],
            "flops": model.flops("matvec_halo", h, w, 1),
        }
    for h, w in GENEX_SHAPES:
        name = f"genex_step_{h}x{w}_s{GENEX_SWEEPS}"
        lowered = jax.jit(
            lambda u, kx, ky, d: model.genex_step(u, kx, ky, d,
                                                  n_sweeps=GENEX_SWEEPS)
        ).lower(spec(h, w), spec(h, w + 1), spec(h, w), spec(h, w))
        yield name, lowered, {
            "entry": "genex_step", "h": h, "w": w, "iters": GENEX_SWEEPS,
            "args": ["u[h,w]", "kx[h,w+1]", "ky[h,w]", "d[h,w]"],
            "outputs": ["u[h,w]", "norms[sweeps]"],
            "flops": model.flops("genex_step", h, w, GENEX_SWEEPS),
        }


def perf_report() -> str:
    """L1 static analysis: VMEM + arithmetic intensity per block shape."""
    lines = ["L1 stencil kernel — static TPU estimate (DESIGN.md §8/§9)",
             f"{'block':>6} {'W':>6} {'VMEM KiB':>9} {'AI flop/B':>10} "
             f"{'bound':>10}"]
    for block in (16, 32, 64, 128):
        for w in (64, 256, 1024, 4096):
            vmem = stencil.vmem_bytes(block, w)
            flops = 9 * block * w
            # HBM traffic per block: read p (3 shifted views hit the same
            # HBM lines; count once) + kx + ky + d, write out.
            bytes_moved = (block * (w + 2) + block * (w + 3)
                           + 2 * block * (w + 2) + block * w) * 4
            ai = flops / bytes_moved
            bound = "HBM-bw" if ai < 100 else "compute"
            lines.append(f"{block:>6} {w:>6} {vmem / 1024:>9.1f} "
                         f"{ai:>10.3f} {bound:>10}")
    lines.append("MXU idle by construction (no contraction dim); roofline "
                 "= HBM bandwidth. Default block=64 keeps VMEM < 8 MiB at "
                 "W=4096 with double-buffering headroom.")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--report", action="store_true",
                    help="print the L1 static perf analysis and exit")
    args = ap.parse_args()

    if args.report:
        print(perf_report())
        return

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "artifacts": {}}
    for name, lowered, meta in build_entries():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = fname
        meta["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        meta["hlo_bytes"] = len(text)
        manifest["artifacts"][name] = meta
        print(f"wrote {path} ({len(text)} chars, {meta['flops']} flops)")
    manifest["vmem_block64_w4096_bytes"] = stencil.vmem_bytes(64, 4096)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')} "
          f"({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
