"""Pure-jnp oracle for the L1 stencil kernel and the L2 CG solve.

Everything here is straight-line jax.numpy — no pallas — and is the
correctness reference for pytest (and, transitively, for the numbers the
rust runtime executes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_operator_ref(p: jax.Array, kx: jax.Array, ky: jax.Array,
                       d: jax.Array) -> jax.Array:
    """Reference 5-point TeaLeaf operator; shapes as stencil.apply_operator."""
    pc = jnp.pad(p, ((1, 1), (1, 1)))
    center = pc[1:-1, 1:-1]
    north = pc[:-2, 1:-1]
    south = pc[2:, 1:-1]
    west = pc[1:-1, :-2]
    east = pc[1:-1, 2:]
    ky_south = jnp.concatenate([ky[1:], jnp.zeros_like(ky[:1])], axis=0)
    return (d * center
            - ky * north
            - ky_south * south
            - kx[:, :-1] * west
            - kx[:, 1:] * east)


def build_coefficients(h: int, w: int, *, dt: float = 0.5,
                       conductivity: float = 1.0, dtype=jnp.float32):
    """TeaLeaf-style coefficients: zero-flux boundaries, SPD operator.

    Returns (kx, ky, d) with kx: (h, w+1), ky/d: (h, w).
    """
    kx = jnp.full((h, w + 1), dt * conductivity, dtype)
    ky = jnp.full((h, w), dt * conductivity, dtype)
    # zero-flux physical boundary faces -> operator stays SPD.
    kx = kx.at[:, 0].set(0.0).at[:, -1].set(0.0)
    ky = ky.at[0, :].set(0.0)
    ky_south = jnp.concatenate([ky[1:], jnp.zeros_like(ky[:1])], axis=0)
    d = 1.0 + kx[:, :-1] + kx[:, 1:] + ky + ky_south
    return kx, ky, d


def cg_solve_ref(b: jax.Array, kx: jax.Array, ky: jax.Array, d: jax.Array,
                 n_iters: int):
    """Fixed-iteration CG on the reference operator.

    Returns (x, rr_history) where rr_history[k] = ||r_k||^2 after k+1
    iterations (matching model.cg_solve's scan outputs).
    """
    x = jnp.zeros_like(b)
    r = b
    p = r
    rr = jnp.vdot(r, r)
    hist = []
    for _ in range(n_iters):
        ap = apply_operator_ref(p, kx, ky, d)
        alpha = rr / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rr_new = jnp.vdot(r, r)
        beta = rr_new / rr
        p = r + beta * p
        rr = rr_new
        hist.append(rr)
    return x, jnp.stack(hist)
