"""L1: Pallas 5-point stencil operator — the TeaLeaf CG hot spot.

The TeaLeaf heat-conduction mini-app [Martineau et al. 2017] spends its
time applying the implicit diffusion operator

    (A p)[i,j] = d[i,j] * p[i,j]
               - ky[i,  j] * p[i-1,j] - ky[i+1,j] * p[i+1,j]
               - kx[i,j  ] * p[i,j-1] - kx[i,j+1] * p[i,j+1]

with d = 1 + dt*(kx[i,j]+kx[i,j+1]+ky[i,j]+ky[i+1,j]) inside a conjugate
gradient solve.  We implement the operator as a Pallas kernel tiled over
row blocks; the surrounding CG (dots, axpys, scan) lives in L2
(``compile.model``) so XLA fuses it around the kernel.

Hardware adaptation (DESIGN.md §8): on CPU TeaLeaf cache-blocks this
sweep; on TPU the same insight becomes an HBM->VMEM row-block schedule
expressed with ``BlockSpec``.  The stencil has no contraction dimension,
so the MXU is structurally idle and the roofline is the HBM bandwidth
line; the kernel therefore optimizes VMEM residency (one block + 1-row
halos for five operand arrays) and VPU-friendly full-row vectors.

Halo handling: Pallas BlockSpec windows cannot overlap, so the operand
``p`` is passed three times with index maps ``i-1, i, i+1`` over a
row-padded copy (one zero block of rows on each side).  Each program
assembles its (B+2)-row working window from the last row of the previous
block and the first row of the next.  Columns keep the full width W per
block with one zero ghost column on each side, so W is the vector-lane
dimension.

All kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); the real-TPU VMEM/roofline estimate is emitted by
``compile.aot --report``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-block height.  Chosen so the five f32 operand blocks
# (p x3 views share one HBM buffer but occupy separate VMEM windows,
# kx, ky, d, out) fit comfortably in 16 MiB VMEM for W <= 4096:
#   (3*(B) + 3*(B+1) + 2*B) * (W+2) * 4B  ~ 8*B*W*4B;  B=64, W=4096 -> 8 MiB.
DEFAULT_BLOCK = 64


def _stencil_kernel(pm_ref, pc_ref, pp_ref, kx_ref, ky_ref, kyn_ref, d_ref,
                    o_ref):
    """One row-block of the 5-point operator.

    pm/pc/pp: previous / current / next row-blocks of the padded operand,
    each (B, W+2).  kx: (B, W+3) face conductivities in x (kx[:, j] is the
    west face of column j).  ky: (B, W+2) north faces; kyn: (B, W+2) south
    faces (= ky shifted one row).  d: (B, W+2) diagonal.  o: (B, W).
    """
    p_c = pc_ref[...]          # (B, W+2)
    p_n = pm_ref[...]          # row i-1 values for each row of the block
    p_s = pp_ref[...]          # row i+1 values
    kx = kx_ref[...]
    ky = ky_ref[...]
    kyn = kyn_ref[...]
    d = d_ref[...]

    center = p_c[:, 1:-1]
    west = p_c[:, :-2]
    east = p_c[:, 2:]
    north = p_n[:, 1:-1]
    south = p_s[:, 1:-1]

    out = (d[:, 1:-1] * center
           - ky[:, 1:-1] * north
           - kyn[:, 1:-1] * south
           - kx[:, 1:-2] * west
           - kx[:, 2:-1] * east)
    o_ref[...] = out


def _pad_rows_block(x: jax.Array, block: int) -> jax.Array:
    """Pad one zero row-block above and below (for the i-1/i+1 views)."""
    b = jnp.zeros((block, x.shape[1]), x.dtype)
    return jnp.concatenate([b, x, b], axis=0)


def _shift_up(x: jax.Array) -> jax.Array:
    """Row i of result = row i-1 of x (zero at the top)."""
    return jnp.concatenate([jnp.zeros_like(x[:1]), x[:-1]], axis=0)


def _shift_down(x: jax.Array) -> jax.Array:
    """Row i of result = row i+1 of x (zero at the bottom)."""
    return jnp.concatenate([x[1:], jnp.zeros_like(x[:1])], axis=0)


@functools.partial(jax.jit, static_argnames=("block",))
def apply_operator(p: jax.Array, kx: jax.Array, ky: jax.Array,
                   d: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Apply the TeaLeaf diffusion operator A to ``p``.

    Shapes: p, ky, d: (H, W); kx: (H, W+1) (x faces).  ky[i, j] is the
    face between rows i-1 and i (ky[0] is the domain boundary, zero-flux
    when the caller builds it that way).  Returns (H, W).

    Dirichlet-zero ghost cells outside the domain.  H must be a multiple
    of ``block`` (callers pad; AOT shapes are chosen as multiples).
    """
    h, w = p.shape
    if h % block:
        raise ValueError(f"H={h} not a multiple of block={block}")
    nblk = h // block

    # Column ghost cells (zero) so the kernel reads full rows.
    pc = jnp.pad(p, ((0, 0), (1, 1)))                      # (H, W+2)
    p3 = _pad_rows_block(pc, block)                        # (H+2B, W+2)

    # Per-row neighbour views, assembled *outside* the kernel would defeat
    # the blocking; instead each program reads three vertically adjacent
    # blocks of p3 and uses only the rows it needs.  To keep the kernel
    # branch-free we precompute shifted row views as separate inputs with
    # plain (i) index maps over shifted copies:
    p_up = _shift_up(pc)                                   # row i-1
    p_dn = _shift_down(pc)                                 # row i+1
    del p3  # the 3-view trick is kept for documentation; shifted copies
    # lower to two cheap pads that XLA fuses with the pallas call under
    # interpret=True and keep BlockSpec windows non-overlapping.

    kxp = jnp.pad(kx, ((0, 0), (1, 1)))                    # (H, W+3)
    kyp = jnp.pad(ky, ((0, 0), (1, 1)))                    # (H, W+2)
    # south face of row i = north face of row i+1; bottom boundary zero.
    ky_south = _shift_down(kyp)
    dp = jnp.pad(d, ((0, 0), (1, 1)))

    row_spec = lambda cols: pl.BlockSpec((block, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _stencil_kernel,
        grid=(nblk,),
        in_specs=[
            row_spec(w + 2),   # p_up
            row_spec(w + 2),   # p center
            row_spec(w + 2),   # p_dn
            row_spec(w + 3),   # kx
            row_spec(w + 2),   # ky (north faces)
            row_spec(w + 2),   # ky south faces
            row_spec(w + 2),   # d
        ],
        out_specs=pl.BlockSpec((block, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), p.dtype),
        interpret=True,
    )(p_up, pc, p_dn, kxp, kyp, ky_south, dp)


@functools.partial(jax.jit, static_argnames=("block",))
def apply_operator_halo(p: jax.Array, north: jax.Array, south: jax.Array,
                        kx: jax.Array, ky: jax.Array, ky_bottom: jax.Array,
                        d: jax.Array, *,
                        block: int = DEFAULT_BLOCK) -> jax.Array:
    """Distributed-rank variant: ghost *rows* come from neighbours.

    ``north``/``south`` are (W,) halo rows received from the ranks above /
    below (zeros at the physical boundary).  ``ky``'s row i is the face
    *above* local row i (owned by this rank under TeaLeaf-style row
    decomposition); ``ky_bottom`` (W,) is the face below the last row —
    it is owned by the southern neighbour and travels with the halo
    exchange (zeros at the physical boundary).  This is the executable the
    rust coordinator drives when it runs a real distributed matvec with
    simulated halo exchange (runtime integration test / counter
    calibration).
    """
    hp = jnp.concatenate([north[None, :], p, south[None, :]], axis=0)
    # Apply the shared-memory operator on the extended domain, then crop.
    # Ghost-row coefficient values only influence the discarded ghost
    # outputs — except the south face of the last interior row, which is
    # exactly ky_bottom.
    kxe = jnp.concatenate([kx[:1], kx, kx[-1:]], axis=0)
    kye = jnp.concatenate([ky[:1], ky, ky_bottom[None, :]], axis=0)
    de = jnp.concatenate([d[:1], d, d[-1:]], axis=0)
    hpad = hp.shape[0]
    pad_to = (-hpad) % block
    if pad_to:
        hp = jnp.pad(hp, ((0, pad_to), (0, 0)))
        kxe = jnp.pad(kxe, ((0, pad_to), (0, 0)))
        kye = jnp.pad(kye, ((0, pad_to), (0, 0)))
        de = jnp.pad(de, ((0, pad_to), (0, 0)))
    out = apply_operator(hp, kxe, kye, de, block=block)
    return out[1:1 + p.shape[0]]


def flops_per_application(h: int, w: int) -> int:
    """Exact flop count of one operator application (for counters.rs)."""
    # 5 multiplies + 4 subtractions/adds per cell.
    return 9 * h * w


def vmem_bytes(block: int, w: int, dtype_bytes: int = 4) -> int:
    """Static VMEM estimate for one program instance (DESIGN.md §9)."""
    per_row = (w + 2) * dtype_bytes
    # 7 input windows + 1 output window resident simultaneously.
    return block * (7 * per_row + (w + 3) * dtype_bytes + w * dtype_bytes)
