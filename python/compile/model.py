"""L2: the TeaLeaf-CG compute graph in JAX, calling the L1 Pallas kernel.

Three exported entry points (all AOT-lowered to HLO text by ``aot.py``):

* ``cg_solve``        — fixed-iteration CG on one rank's subdomain; this is
                        what the paper's performance jobs run.
* ``matvec_halo``     — one distributed operator application with explicit
                        north/south halo rows; the rust coordinator drives
                        it per-rank with simulated halo exchange (the
                        runtime integration test and counter calibration).
* ``genex_step``      — the synthetic GENE-X-like timestep: a few stencil
                        sweeps + nonlinear pointwise update, used by the
                        CI case-study app so its numerics are real too.

Everything is fp32, fixed shapes per artifact (XLA AOT is
shape-specialized; the rust runtime registry picks the artifact for a
rank's subdomain and the simulator's work model extrapolates counters for
untabulated sizes — DESIGN.md §7).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import stencil
from compile.kernels import ref


def _cg_iteration(carry, _, kx, ky, d, block):
    x, r, p, rr = carry
    ap = stencil.apply_operator(p, kx, ky, d, block=block)
    alpha = rr / jnp.vdot(p, ap)
    x = x + alpha * p
    r = r - alpha * ap
    rr_new = jnp.vdot(r, r)
    beta = rr_new / rr
    p = r + beta * p
    return (x, r, p, rr_new), rr_new


@functools.partial(jax.jit, static_argnames=("n_iters", "block"))
def cg_solve(b: jax.Array, kx: jax.Array, ky: jax.Array, d: jax.Array,
             *, n_iters: int = 50, block: int = stencil.DEFAULT_BLOCK):
    """Fixed-iteration CG solve of A x = b on one subdomain.

    Returns (x, rr_history[n_iters]).  ``lax.scan`` keeps the lowered HLO
    compact (one fused iteration body) instead of unrolling n_iters copies
    of the kernel.
    """
    x0 = jnp.zeros_like(b)
    rr0 = jnp.vdot(b, b)
    body = functools.partial(_cg_iteration, kx=kx, ky=ky, d=d, block=block)
    (x, _, _, _), hist = jax.lax.scan(body, (x0, b, b, rr0), None,
                                      length=n_iters)
    return x, hist


@functools.partial(jax.jit, static_argnames=("block",))
def matvec_halo(p: jax.Array, north: jax.Array, south: jax.Array,
                kx: jax.Array, ky: jax.Array, ky_bottom: jax.Array,
                d: jax.Array, *, block: int = stencil.DEFAULT_BLOCK):
    """Distributed operator application (see stencil.apply_operator_halo)."""
    return (stencil.apply_operator_halo(p, north, south, kx, ky, ky_bottom,
                                        d, block=block),)


@functools.partial(jax.jit, static_argnames=("n_sweeps", "block"))
def genex_step(u: jax.Array, kx: jax.Array, ky: jax.Array, d: jax.Array,
               *, n_sweeps: int = 4, block: int = stencil.DEFAULT_BLOCK):
    """Synthetic GENE-X-like timestep: n_sweeps stencil applications with a
    stabilized nonlinear pointwise term (tanh keeps values bounded so long
    CI histories never diverge)."""
    def body(u, _):
        au = stencil.apply_operator(u, kx, ky, d, block=block)
        u = u - 0.1 * au + 0.01 * jnp.tanh(u)
        return u, jnp.vdot(u, u)
    u, norms = jax.lax.scan(body, u, None, length=n_sweeps)
    return u, norms


def initial_condition(h: int, w: int, dtype=jnp.float32) -> jax.Array:
    """Deterministic smooth-bump initial field (matches rust's generator)."""
    i = jnp.arange(h, dtype=dtype)[:, None] / h
    j = jnp.arange(w, dtype=dtype)[None, :] / w
    return (jnp.sin(3.14159265 * i) * jnp.sin(3.14159265 * j)
            + 0.1 * jnp.sin(9.0 * i * j)).astype(dtype)


def flops(entry: str, h: int, w: int, n_iters: int) -> int:
    """Analytic flop counts per entry point (consumed by counters.rs via
    the artifact manifest)."""
    stencil_f = ref_stencil_flops = stencil.flops_per_application(h, w)
    cells = h * w
    if entry == "cg_solve":
        # per iter: matvec + 2 vdots (2*2N) + 2 axpy (2*2N) + p update (2N)
        per_iter = stencil_f + 4 * cells + 4 * cells + 2 * cells + 4
        return n_iters * per_iter + 2 * cells
    if entry == "matvec_halo":
        return ref_stencil_flops
    if entry == "genex_step":
        # per sweep: matvec + axpy-ish update (4N) + tanh (~10N) + vdot (2N)
        return n_iters * (stencil_f + 16 * cells)
    raise ValueError(entry)
